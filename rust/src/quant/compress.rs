//! Compression accounting (the paper's "Average bits" / "Compression
//! ratio" columns and the §A.8 space-complexity model).

/// Memory-weighted average bitwidth across feature maps:
/// Σ_l Σ_i dim_l·b_i / Σ_l N_l·dim_l  (paper Eq. 19 numerator form).
pub fn average_bits(maps: &[(&[u8], usize)]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (bits, dim) in maps {
        num += bits.iter().map(|&b| b as f64).sum::<f64>() * *dim as f64;
        den += bits.len() as f64 * *dim as f64;
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

/// 32 / avg_bits — compression vs the FP32 feature maps.
pub fn compression_ratio(avg_bits: f64) -> f64 {
    if avg_bits <= 0.0 {
        0.0
    } else {
        32.0 / avg_bits
    }
}

/// Quantized feature memory in bytes (Eq. 19): feature payload + one f32
/// step per node per map.
pub fn feature_memory_bytes(maps: &[(&[u8], usize)]) -> usize {
    let mut bits_total = 0usize;
    let mut steps = 0usize;
    for (bits, dim) in maps {
        bits_total += bits.iter().map(|&b| b as usize).sum::<usize>() * dim;
        steps += bits.len();
    }
    bits_total.div_ceil(8) + steps * 4
}

/// FP32 feature memory for the same maps.
pub fn fp32_memory_bytes(maps: &[(&[u8], usize)]) -> usize {
    maps.iter().map(|(bits, dim)| bits.len() * dim * 4).sum()
}

/// Step-size overhead ratio r of Eq. 20 — the paper argues it is
/// negligible; the tests pin that down for our configs.
pub fn step_overhead_ratio(maps: &[(&[u8], usize)]) -> f64 {
    let mut feature_bits = 0.0;
    let mut step_bits = 0.0;
    for (bits, dim) in maps {
        feature_bits += bits.iter().map(|&b| b as f64).sum::<f64>() * *dim as f64;
        step_bits += bits.len() as f64 * 32.0;
    }
    if feature_bits == 0.0 {
        0.0
    } else {
        step_bits / feature_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_bits_weighted() {
        let m1 = vec![2u8; 10];
        let m2 = vec![6u8; 10];
        let maps: Vec<(&[u8], usize)> = vec![(&m1, 1), (&m2, 3)];
        assert!((average_bits(&maps) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn compression_of_paper_headline() {
        // paper: 1.70 avg bits -> 18.6x (table 1 GCN-Cora: 18.8 exact; the
        // paper rounds overall model memory, we check the feature ratio)
        let r = compression_ratio(1.70);
        assert!((r - 18.82).abs() < 0.05);
    }

    #[test]
    fn memory_accounting() {
        let bits = vec![4u8; 100];
        let maps: Vec<(&[u8], usize)> = vec![(&bits, 16)];
        // 100 nodes * 16 dims * 4 bits = 800 bytes payload + 400 step bytes
        assert_eq!(feature_memory_bytes(&maps), 800 + 400);
        assert_eq!(fp32_memory_bytes(&maps), 6400);
    }

    #[test]
    fn step_overhead_negligible_for_wide_features() {
        // Cora-like: 1433-dim input, 2 bits avg
        let bits = vec![2u8; 2708];
        let maps: Vec<(&[u8], usize)> = vec![(&bits, 1433)];
        assert!(step_overhead_ratio(&maps) < 0.02);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(average_bits(&[]), 0.0);
        assert_eq!(compression_ratio(0.0), 0.0);
    }
}
