//! Per-node mixed-precision parameters + the `.bits.bin` loader.
//!
//! `NodeQuantParams` carries the learned per-node (step, bits) of one
//! feature map; `BitsFile` reads the bit vectors exported by
//! `python/compile/aot.py::write_bits_file` (magic "A2QB") that drive the
//! accelerator simulator.

use std::io::Read;
use std::path::Path;

use crate::error::{Error, Result};

use super::uniform;

/// Learned per-node quantization parameters for one feature map.
///
/// Steps are validated and clamped **once at construction** (the model-load
/// boundary): non-finite steps are rejected with a descriptive artifact
/// error, and every step is floored to [`uniform::MIN_STEP`].  This keeps
/// the fp fake-quant path and the integer-code path (which records the
/// step for the Eq. 2 rescale) working off the *same* step value — a raw
/// 0.0 step would otherwise make `rescale_outer` silently zero rows that
/// the fp path quantizes with the clamped step.
#[derive(Debug, Clone)]
pub struct NodeQuantParams {
    pub steps: Vec<f32>,
    pub bits: Vec<u8>,
    pub signed: bool,
}

impl NodeQuantParams {
    pub fn new(steps: Vec<f32>, bits: Vec<u8>, signed: bool) -> Result<Self> {
        if steps.len() != bits.len() {
            return Err(Error::shape("steps/bits length mismatch"));
        }
        if let Some(i) = steps.iter().position(|s| !s.is_finite()) {
            return Err(Error::artifact(format!(
                "non-finite quantization step {} at node {i} (corrupt artifact?)",
                steps[i]
            )));
        }
        // the bucketed integer kernels (quant::pack) dispatch on widths
        // 1..=8; reject wider artifacts here — the load-time validation
        // boundary — instead of panicking per forward in a runner thread
        // (b = 0 stays tolerated: it quantizes every code to 0)
        if let Some(i) = bits.iter().position(|&b| b > 8) {
            return Err(Error::artifact(format!(
                "bitwidth {} at node {i} exceeds the supported 1..=8 range",
                bits[i]
            )));
        }
        let steps = steps
            .into_iter()
            .map(|s| s.max(uniform::MIN_STEP))
            .collect();
        Ok(NodeQuantParams {
            steps,
            bits,
            signed,
        })
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    /// Append one node's `(step, bits)` — the online NNS assignment path
    /// for nodes that arrive after training (`gnn::incremental`).  The
    /// step gets the same [`uniform::MIN_STEP`] floor as construction so
    /// the fp/int step invariant holds for appended entries too (table
    /// steps already carry the floor, making this a no-op in practice).
    pub fn push(&mut self, step: f32, bits: u8) {
        self.steps.push(step.max(uniform::MIN_STEP));
        self.bits.push(bits);
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Fake-quantize a [N, F] feature matrix row-by-row in place.
    pub fn fake_quantize(&self, x: &mut [f32], feat_dim: usize) {
        assert_eq!(x.len(), self.len() * feat_dim);
        for (v, chunk) in x.chunks_exact_mut(feat_dim).enumerate() {
            uniform::fake_quantize_row(chunk, self.steps[v], self.bits[v], self.signed);
        }
    }

    /// Quantize to integer codes, returning codes + per-row steps (for the
    /// Eq. 2 integer-path matmul).
    pub fn quantize_codes(&self, x: &[f32], feat_dim: usize) -> (Vec<i32>, Vec<f32>) {
        assert_eq!(x.len(), self.len() * feat_dim);
        let mut codes = vec![0i32; x.len()];
        for (v, chunk) in x.chunks_exact(feat_dim).enumerate() {
            let s = self.steps[v];
            let b = self.bits[v];
            for (o, &xv) in codes[v * feat_dim..(v + 1) * feat_dim]
                .iter_mut()
                .zip(chunk)
            {
                *o = uniform::quantize_value(xv, s, b, self.signed);
            }
        }
        (codes, self.steps.clone())
    }

    /// Memory-weighted average bitwidth of this map.
    pub fn avg_bits(&self) -> f64 {
        if self.bits.is_empty() {
            return 0.0;
        }
        self.bits.iter().map(|&b| b as f64).sum::<f64>() / self.bits.len() as f64
    }
}

/// Parsed `.bits.bin`: one bit vector per quantized feature map, each with
/// its feature dimension (for memory weighting).
#[derive(Debug, Clone)]
pub struct BitsFile {
    pub maps: Vec<(Vec<u8>, usize)>,
}

impl BitsFile {
    pub fn load(path: &Path) -> Result<BitsFile> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        if buf.len() < 8 || &buf[..4] != b"A2QB" {
            return Err(Error::artifact(format!(
                "{}: not an A2QB file",
                path.display()
            )));
        }
        let n_maps = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        let mut pos = 8;
        let mut maps = Vec::with_capacity(n_maps);
        for _ in 0..n_maps {
            if pos + 8 > buf.len() {
                return Err(Error::artifact("truncated bits file"));
            }
            let count = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
            let dim = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap()) as usize;
            pos += 8;
            if pos + count > buf.len() {
                return Err(Error::artifact("truncated bits payload"));
            }
            maps.push((buf[pos..pos + count].to_vec(), dim));
            pos += count;
        }
        Ok(BitsFile { maps })
    }

    /// Memory-weighted average bits across all maps (paper's "Average bits").
    pub fn avg_bits(&self) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for (bits, dim) in &self.maps {
            num += bits.iter().map(|&b| b as f64).sum::<f64>() * *dim as f64;
            den += bits.len() as f64 * *dim as f64;
        }
        if den == 0.0 {
            0.0
        } else {
            num / den
        }
    }

    /// Histogram over bitwidths 1..=8 pooled across maps.
    pub fn histogram(&self) -> [usize; 8] {
        let mut h = [0usize; 8];
        for (bits, _) in &self.maps {
            for &b in bits {
                let i = (b.clamp(1, 8) - 1) as usize;
                h[i] += 1;
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn rejects_bits_above_eight() {
        // the bucketed kernels dispatch on widths 1..=8 — wider artifacts
        // must fail at the load boundary, not panic per forward
        let err = NodeQuantParams::new(vec![0.1, 0.1], vec![4, 9], true).unwrap_err();
        assert!(format!("{err}").contains("1..=8"));
        // zero stays tolerated (quantizes every code to 0)
        assert!(NodeQuantParams::new(vec![0.1], vec![0], true).is_ok());
    }

    #[test]
    fn fake_quantize_per_row() {
        let p = NodeQuantParams::new(vec![0.1, 0.5], vec![4, 2], true).unwrap();
        let mut x = vec![0.23, -0.9, 0.6, 10.0];
        p.fake_quantize(&mut x, 2);
        assert!((x[0] - 0.2).abs() < 1e-6);
        assert!((x[1] + 0.7).abs() < 1e-6); // clipped to 7 levels * 0.1
        assert!((x[2] - 0.5).abs() < 1e-6);
        assert!((x[3] - 0.5).abs() < 1e-6); // 2-bit: 1 level * 0.5
    }

    #[test]
    fn codes_roundtrip_scales() {
        let p = NodeQuantParams::new(vec![0.1, 0.2], vec![6, 6], true).unwrap();
        let x = vec![0.31, -0.52, 0.4, 0.79];
        let (codes, steps) = p.quantize_codes(&x, 2);
        assert_eq!(codes, vec![3, -5, 2, 4]);
        assert_eq!(steps, vec![0.1, 0.2]);
    }

    #[test]
    fn bits_file_roundtrip() {
        let dir = std::env::temp_dir().join("a2q_bits_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bits.bin");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(b"A2QB").unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        // map 1: 3 nodes, dim 16
        f.write_all(&3u32.to_le_bytes()).unwrap();
        f.write_all(&16u32.to_le_bytes()).unwrap();
        f.write_all(&[2u8, 4, 8]).unwrap();
        // map 2: 2 nodes, dim 32
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&32u32.to_le_bytes()).unwrap();
        f.write_all(&[1u8, 1]).unwrap();
        drop(f);

        let bf = BitsFile::load(&path).unwrap();
        assert_eq!(bf.maps.len(), 2);
        assert_eq!(bf.maps[0].0, vec![2, 4, 8]);
        let want = (2.0 + 4.0 + 8.0) * 16.0 + 2.0 * 32.0;
        let den = 3.0 * 16.0 + 2.0 * 32.0;
        assert!((bf.avg_bits() - want / den).abs() < 1e-12);
        assert_eq!(bf.histogram()[0], 2); // two 1-bit nodes
    }

    #[test]
    fn non_finite_steps_rejected_at_construction() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let err = NodeQuantParams::new(vec![0.1, bad], vec![4, 4], true).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains("non-finite"), "unexpected error: {msg}");
            assert!(msg.contains("node 1"), "should name the offending node: {msg}");
        }
    }

    #[test]
    fn zero_and_negative_steps_clamped_once() {
        use crate::util::prop::{property, Gen};
        let p = NodeQuantParams::new(vec![0.0, -0.3, 0.2], vec![4, 4, 4], true).unwrap();
        assert_eq!(p.steps[0], crate::quant::uniform::MIN_STEP);
        assert_eq!(p.steps[1], crate::quant::uniform::MIN_STEP);
        assert_eq!(p.steps[2], 0.2);
        // the recorded step (Eq. 2 sx) always equals the step the codes
        // were computed with — with a raw 0.0 recorded step the int path
        // would zero rows the fp path doesn't.  Values may diverge by at
        // most ONE code (quantize_value divides by s, fake_quantize_row
        // multiplies by 1/s; the two roundings can straddle a floor
        // boundary), never by a wrong scale.
        property("codes * recorded step tracks fake quant", 50, |g: &mut Gen| {
            let n = g.usize_range(1, 12);
            let f = g.usize_range(1, 8);
            let mut steps = g.vec_uniform(n, 0.0, 0.2);
            for s in steps.iter_mut() {
                if g.bool(0.3) {
                    *s = 0.0; // inject the degenerate case
                }
            }
            let bits: Vec<u8> = (0..n).map(|_| g.usize_range(1, 9) as u8).collect();
            let signed = g.bool(0.5);
            let p = NodeQuantParams::new(steps, bits, signed).unwrap();
            let x = g.vec_normal(n * f, 1.0);
            let mut fake = x.clone();
            p.fake_quantize(&mut fake, f);
            let (codes, rec_steps) = p.quantize_codes(&x, f);
            // the recorded steps ARE the construction-clamped steps
            assert_eq!(rec_steps, p.steps);
            for v in 0..n {
                for j in 0..f {
                    let deq = codes[v * f + j] as f32 * rec_steps[v];
                    let diff = (deq - fake[v * f + j]).abs();
                    assert!(
                        diff <= rec_steps[v] + 1e-12,
                        "node {v} col {j}: |{deq} - {}| > step {}",
                        fake[v * f + j],
                        rec_steps[v]
                    );
                }
            }
        });
    }

    #[test]
    fn bits_file_rejects_garbage() {
        let dir = std::env::temp_dir().join("a2q_bits_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bits.bin");
        std::fs::write(&path, b"XXXX").unwrap();
        assert!(BitsFile::load(&path).is_err());
    }
}
