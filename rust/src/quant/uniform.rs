//! Uniform symmetric quantizer — Eq. 1 / Eq. 9 of the paper, bit-exact with
//! the python/jnp reference (`kernels/ref.py::quantize_ref`).

/// A quantized row: integer codes + the (step, bits) that decode them.
#[derive(Debug, Clone)]
pub struct Quantized {
    pub codes: Vec<i32>,
    pub step: f32,
    pub bits: u8,
    pub signed: bool,
}

/// Smallest admissible quantization step.  Every code path that divides or
/// multiplies by a step clamps to this floor, and `NodeQuantParams::new`
/// applies it once at construction so the *recorded* step (the `sx` of the
/// Eq. 2 rescale, the step stored by `quant::pack`) always equals the step
/// the codes were computed with — a raw 0.0 step would otherwise zero the
/// integer path's output rows while the fp path quantizes with the clamped
/// value.
pub const MIN_STEP: f32 = 1e-9;

/// Positive level count: 2^{b-1}-1 signed, 2^b-1 unsigned (post-ReLU maps).
#[inline]
pub fn levels(bits: u8, signed: bool) -> i32 {
    if signed {
        (1i32 << (bits.max(1) - 1)) - 1
    } else {
        ((1i64 << bits.min(31)) - 1) as i32
    }
}

/// Quantize one value (Eq. 1): code = sign(x)·min(⌊|x|/s + 0.5⌋, levels).
#[inline]
pub fn quantize_value(x: f32, step: f32, bits: u8, signed: bool) -> i32 {
    let s = step.max(MIN_STEP);
    let lv = levels(bits, signed);
    let mag = ((x.abs() / s) + 0.5).floor().min(lv as f32) as i32;
    let code = if x < 0.0 { -mag } else { mag };
    if signed {
        code
    } else {
        code.max(0)
    }
}

/// Quantize a row with shared (step, bits).
pub fn quantize_row(row: &[f32], step: f32, bits: u8, signed: bool) -> Quantized {
    Quantized {
        codes: row
            .iter()
            .map(|&x| quantize_value(x, step, bits, signed))
            .collect(),
        step,
        bits,
        signed,
    }
}

/// Dequantize codes back to f32: x_q = s · code.
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    q.codes.iter().map(|&c| c as f32 * q.step).collect()
}

/// Fake-quantize in place (quantize → dequantize), the form the fp32-side
/// emulation uses.
///
/// §Perf: the row loop precomputes `1/s` (divisions cost ~4× a multiply on
/// this core) and uses a branchless magnitude clamp so LLVM vectorizes it —
/// 3.4× over the naive per-element `quantize_value` loop (EXPERIMENTS.md
/// §Perf iteration 1).
pub fn fake_quantize_row(row: &mut [f32], step: f32, bits: u8, signed: bool) {
    let s = step.max(MIN_STEP);
    let inv = 1.0 / s;
    let lv = levels(bits, signed) as f32;
    if signed {
        for x in row.iter_mut() {
            let mag = (x.abs() * inv + 0.5).floor().min(lv);
            *x = (mag * s).copysign(*x);
        }
    } else {
        for x in row.iter_mut() {
            let mag = (x.max(0.0) * inv + 0.5).floor().min(lv);
            *x = mag * s;
        }
    }
}

/// L1 quantization error (1/d)·|x_q − x|₁ — the paper's Local-Gradient
/// supervision signal E (§3.2), used here for diagnostics and tests.
pub fn quant_error(row: &[f32], step: f32, bits: u8, signed: bool) -> f32 {
    if row.is_empty() {
        return 0.0;
    }
    let s = step.max(MIN_STEP);
    let sum: f32 = row
        .iter()
        .map(|&x| (quantize_value(x, s, bits, signed) as f32 * s - x).abs())
        .sum();
    sum / row.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{property, Gen};

    #[test]
    fn known_values() {
        // s=0.1, b=4 signed: levels=7
        assert_eq!(quantize_value(0.25, 0.1, 4, true), 3); // round half up: 2.5+0.5 -> 3
        assert_eq!(quantize_value(-0.24, 0.1, 4, true), -2);
        assert_eq!(quantize_value(5.0, 0.1, 4, true), 7); // clipped
        assert_eq!(quantize_value(-5.0, 0.1, 4, true), -7);
        assert_eq!(quantize_value(-0.3, 0.1, 4, false), 0); // unsigned clamps
    }

    #[test]
    fn levels_table() {
        assert_eq!(levels(4, true), 7);
        assert_eq!(levels(4, false), 15);
        assert_eq!(levels(1, true), 0);
        assert_eq!(levels(8, true), 127);
    }

    #[test]
    fn codes_bounded_property() {
        property("codes within levels", 100, |g: &mut Gen| {
            let bits = g.usize_range(1, 9) as u8;
            let signed = g.bool(0.5);
            let step = g.f32_range(0.005, 0.5);
            let x = g.f32_range(-20.0, 20.0);
            let c = quantize_value(x, step, bits, signed);
            let lv = levels(bits, signed);
            assert!(c.abs() <= lv, "code {c} exceeds levels {lv}");
            if !signed {
                assert!(c >= 0);
            }
        });
    }

    #[test]
    fn inrange_error_below_half_step_property() {
        property("|xq-x| <= s/2 in range", 100, |g: &mut Gen| {
            let bits = g.usize_range(2, 9) as u8;
            let step = g.f32_range(0.01, 0.3);
            let lv = levels(bits, true) as f32;
            let x = g.f32_range(-0.99, 0.99) * step * lv;
            let xq = quantize_value(x, step, bits, true) as f32 * step;
            assert!(
                (xq - x).abs() <= step / 2.0 + 1e-6,
                "x={x} xq={xq} step={step}"
            );
        });
    }

    #[test]
    fn roundtrip_monotone_property() {
        // quantization preserves ordering up to one step
        property("quantize monotone", 50, |g: &mut Gen| {
            let step = g.f32_range(0.01, 0.2);
            let a = g.f32_range(-2.0, 2.0);
            let b = a + g.f32_range(0.0, 2.0);
            let qa = quantize_value(a, step, 6, true);
            let qb = quantize_value(b, step, 6, true);
            assert!(qb >= qa);
        });
    }

    #[test]
    fn quant_error_zero_on_lattice() {
        let row = [0.2f32, -0.4, 0.0, 0.6];
        assert!(quant_error(&row, 0.2, 6, true) < 1e-7);
        // and positive off-lattice
        let row2 = [0.25f32];
        assert!(quant_error(&row2, 0.2, 6, true) > 0.01);
    }

    #[test]
    fn fake_quantize_matches_quantize_dequantize() {
        let mut row = vec![0.13f32, -0.7, 2.5];
        let q = quantize_row(&row, 0.1, 5, true);
        fake_quantize_row(&mut row, 0.1, 5, true);
        assert_eq!(row, dequantize(&q));
    }
}
