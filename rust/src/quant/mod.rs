//! Quantization substrate (L3 mirror of `python/compile/quantize.py`).
//!
//! The rust side never *learns* quantization parameters (training is
//! build-time python); it applies them: uniform fake-quant (Eq. 1),
//! per-node mixed precision, the Nearest Neighbor Strategy runtime lookup
//! (Algorithm 1, binary search over sorted q_max exactly as the paper's
//! comparator array), bitwidth-bucketed bit-packed feature storage with
//! per-bitwidth integer matmul kernels, and the compression accounting
//! behind the paper's "Average bits" / "Compression ratio" columns.

pub mod compress;
pub mod mixed;
pub mod nns;
pub mod pack;
pub mod uniform;

pub use compress::{average_bits, compression_ratio, feature_memory_bytes};
pub use mixed::{BitsFile, NodeQuantParams};
pub use nns::NnsTable;
pub use pack::{pack_rows, pack_rows_subset, PackedFeatures};
pub use uniform::{dequantize, quantize_row, quantize_value, Quantized};
