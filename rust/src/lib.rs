pub mod accel;
pub mod coordinator;
pub mod error;
pub mod gnn;
pub mod graph;
pub mod harness;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
pub use error::{Error, Result};
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("A2Q_ARTIFACTS") { return dir.into(); }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.is_dir() { return cand; }
        if !cur.pop() { return "artifacts".into(); }
    }
}
