pub mod accel;
pub mod coordinator;
pub mod error;
pub mod gnn;
pub mod graph;
pub mod harness;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
pub use error::{Error, Result};
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Locate the `artifacts/` directory: `$A2Q_ARTIFACTS` if set, else the
/// nearest `artifacts/` walking up from the current directory.
///
/// Returns an error (instead of a silently-relative `"artifacts"`) when
/// the walk finds nothing, so CI failures name the actual problem.
pub fn artifacts_dir_checked() -> Result<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("A2Q_ARTIFACTS") {
        return Ok(dir.into());
    }
    let start = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut cur = start.clone();
    loop {
        let cand = cur.join("artifacts");
        if cand.is_dir() {
            return Ok(cand);
        }
        if !cur.pop() {
            return Err(Error::Config(format!(
                "no artifacts/ directory found walking up from {} — run \
                 `make artifacts` or set A2Q_ARTIFACTS",
                start.display()
            )));
        }
    }
}

/// Infallible variant used by binaries and benches: falls back to the
/// relative `"artifacts"` path, logging the fallback to stderr so a wrong
/// working directory is diagnosable rather than silent.
pub fn artifacts_dir() -> std::path::PathBuf {
    match artifacts_dir_checked() {
        Ok(dir) => dir,
        Err(e) => {
            eprintln!("a2q: {e}; falling back to ./artifacts");
            "artifacts".into()
        }
    }
}

#[cfg(test)]
mod tests {
    // NOTE: deliberately no std::env::set_var here — mutating the
    // environment races with concurrent getenv in parallel unit tests
    // (ParallelConfig::from_env, prop::property), which is UB on glibc.
    #[test]
    fn artifacts_dir_agrees_with_checked_variant() {
        match super::artifacts_dir_checked() {
            Ok(dir) => assert_eq!(super::artifacts_dir(), dir),
            Err(e) => {
                assert!(format!("{e}").contains("artifacts"));
                assert_eq!(super::artifacts_dir(), std::path::PathBuf::from("artifacts"));
            }
        }
    }
}
