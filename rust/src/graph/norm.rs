//! GCN normalization and edge-form expansion.
//!
//! Builds the `(src, dst, gcn_w, sum_w)` edge arrays used by both the
//! native inference path and the PJRT artifacts (identical to
//! `python/compile/models.py::build_edges`): self-loops appended, GCN
//! weights `(d̃_s d̃_d)^{-1/2}`, and `sum_w` masking self-loops out of the
//! GIN neighbour sum.  Per the paper's Proof 2, Â itself is never
//! quantized — aggregation runs on these f32 weights / fixed-point adds.

use crate::util::threadpool::{self, ParallelConfig};

use super::csr::Csr;
use super::delta::DeltaApplied;

/// Edge-form graph with precomputed normalization weights.
#[derive(Debug, Clone)]
pub struct EdgeForm {
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    /// (d̃_s · d̃_d)^{-1/2} with self-loops (GCN aggregation weights)
    pub gcn_w: Vec<f32>,
    /// 1.0 for real edges, 0.0 for the appended self-loops (GIN mask)
    pub sum_w: Vec<f32>,
    pub num_nodes: usize,
}

impl EdgeForm {
    /// Expand a CSR into edge form, appending self-loops.
    pub fn from_csr(csr: &Csr) -> EdgeForm {
        let n = csr.num_nodes();
        let e = csr.num_edges();
        let mut src = Vec::with_capacity(e + n);
        let mut dst = Vec::with_capacity(e + n);
        for v in 0..n {
            for &s in csr.in_neighbors(v) {
                src.push(s as i32);
                dst.push(v as i32);
            }
        }
        for v in 0..n {
            src.push(v as i32);
            dst.push(v as i32);
        }
        // d̃ = in-degree + 1 (self loop)
        let mut dtilde = vec![1.0f64; n];
        for v in 0..n {
            dtilde[v] += csr.in_degree(v) as f64;
        }
        let gcn_w: Vec<f32> = src
            .iter()
            .zip(&dst)
            .map(|(&s, &d)| (1.0 / (dtilde[s as usize] * dtilde[d as usize]).sqrt()) as f32)
            .collect();
        let mut sum_w = vec![1.0f32; e + n];
        for w in sum_w[e..].iter_mut() {
            *w = 0.0;
        }
        EdgeForm {
            src,
            dst,
            gcn_w,
            sum_w,
            num_nodes: n,
        }
    }

    pub fn num_edges(&self) -> usize {
        self.src.len()
    }

    /// Σ_e w_e · x[src_e] → out[dst_e]   (the aggregation phase), using
    /// the process-default parallelism budget.  Builds a destination
    /// grouping per call; hot paths that aggregate repeatedly should build
    /// an [`AggregationPlan`] once and reuse it.
    pub fn aggregate(&self, x: &[f32], feat_dim: usize, weights: &[f32]) -> Vec<f32> {
        self.plan()
            .aggregate_with(x, feat_dim, &self.src, weights, &threadpool::global_parallelism())
    }

    /// Serial edge-order scatter — the reference implementation the
    /// parallel gather is verified against (identical float add order per
    /// destination, hence bitwise-equal output).
    pub fn aggregate_serial(&self, x: &[f32], feat_dim: usize, weights: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.num_nodes * feat_dim];
        for ((&s, &d), &w) in self.src.iter().zip(&self.dst).zip(weights) {
            if w == 0.0 {
                continue;
            }
            let srow = &x[s as usize * feat_dim..(s as usize + 1) * feat_dim];
            let orow = &mut out[d as usize * feat_dim..(d as usize + 1) * feat_dim];
            for (o, v) in orow.iter_mut().zip(srow) {
                *o += w * v;
            }
        }
        out
    }

    /// Build the destination-grouped execution plan for these edges.
    pub fn plan(&self) -> AggregationPlan {
        AggregationPlan::build(&self.dst, self.num_nodes)
    }

    /// The real-edge block of a [`Self::from_csr`]-shaped form: GCN
    /// weights of the CSR's `e` edges, in dst-major CSR order (the layout
    /// `from_csr` emits — real edges first, self-loops last).  The shard
    /// builder (`graph::shard`) copies per-edge weights through these
    /// views so sharded and global aggregation read identical bits.
    pub fn gcn_w_real(&self, e: usize) -> &[f32] {
        debug_assert_eq!(e + self.num_nodes, self.gcn_w.len());
        &self.gcn_w[..e]
    }

    /// The trailing self-loop block of a [`Self::from_csr`]-shaped form:
    /// one GCN weight per node, indexed by node id.
    pub fn gcn_w_self(&self, e: usize) -> &[f32] {
        debug_assert_eq!(e + self.num_nodes, self.gcn_w.len());
        &self.gcn_w[e..]
    }

    /// Incrementally splice this edge form (which must be
    /// `EdgeForm::from_csr(old_csr)`) into the post-delta one — bitwise
    /// identical to `EdgeForm::from_csr(&applied.csr)`, property-tested
    /// below.
    ///
    /// [`Self::from_csr`] pays one `(d̃_s·d̃_d)^{-1/2}` (f64 mul + sqrt)
    /// per edge; after a small delta almost every weight is unchanged, so
    /// this splice copies clean weights through and recomputes only edges
    /// with a degree-changed endpoint (plus the rows whose neighbour list
    /// itself changed).  d̃ is integer-valued (`1 + in_degree` in f64), so
    /// a freshly computed weight for an untouched edge would reproduce the
    /// old bits anyway — copying just skips the arithmetic.
    pub fn apply_delta(&self, old_csr: &Csr, applied: &DeltaApplied) -> EdgeForm {
        let new_csr = &applied.csr;
        let n_old = applied.prev_nodes;
        let n_new = new_csr.num_nodes();
        let e_old = old_csr.num_edges();
        let e_new = new_csr.num_edges();
        debug_assert_eq!(old_csr.num_nodes(), n_old);
        debug_assert_eq!(self.num_edges(), e_old + n_old);

        let mut dtilde = vec![1.0f64; n_new];
        for (v, d) in dtilde.iter_mut().enumerate() {
            *d += new_csr.in_degree(v) as f64;
        }
        let mut src = Vec::with_capacity(e_new + n_new);
        let mut dst = Vec::with_capacity(e_new + n_new);
        let mut gcn_w = Vec::with_capacity(e_new + n_new);
        for v in 0..n_new {
            let clean_row = v < n_old && !applied.row_changed[v];
            for (k, &s) in new_csr.in_neighbors(v).iter().enumerate() {
                src.push(s as i32);
                dst.push(v as i32);
                let su = s as usize;
                if clean_row && !applied.deg_changed[su] {
                    // clean row ⇒ same (src, dst) pair at the same in-row
                    // offset of the old form, and neither endpoint's d̃
                    // moved ⇒ the old weight is bit-exact
                    gcn_w.push(self.gcn_w[old_csr.indptr[v] as usize + k]);
                } else {
                    gcn_w.push((1.0 / (dtilde[su] * dtilde[v]).sqrt()) as f32);
                }
            }
        }
        for v in 0..n_new {
            src.push(v as i32);
            dst.push(v as i32);
            if v < n_old && !applied.deg_changed[v] {
                gcn_w.push(self.gcn_w[e_old + v]);
            } else {
                gcn_w.push((1.0 / (dtilde[v] * dtilde[v]).sqrt()) as f32);
            }
        }
        let mut sum_w = vec![1.0f32; e_new + n_new];
        for w in sum_w[e_new..].iter_mut() {
            *w = 0.0;
        }
        EdgeForm {
            src,
            dst,
            gcn_w,
            sum_w,
            num_nodes: n_new,
        }
    }
}

/// Destination-grouped view of an edge list: for every destination node,
/// the edge slots targeting it.  An edge-order scatter writes to arbitrary
/// output rows, so it cannot be split across threads; grouping by
/// destination gives each output row exactly one owner, making the gather
/// embarrassingly row-parallel.  Building the plan is O(E) (a stable
/// counting sort) — ~1/F of one aggregation pass — and the plan is
/// reusable across layers and requests since it depends only on `dst`.
#[derive(Debug, Clone, PartialEq)]
pub struct AggregationPlan {
    /// edge indices grouped by destination, stable within a group
    edge_order: Vec<u32>,
    /// per-destination extent into `edge_order`, length `num_nodes + 1`
    offsets: Vec<u32>,
    num_nodes: usize,
}

impl AggregationPlan {
    /// Group `dst` (entries in `0..num_nodes`) by destination.
    pub fn build(dst: &[i32], num_nodes: usize) -> AggregationPlan {
        let mut offsets = vec![0u32; num_nodes + 1];
        for &d in dst {
            offsets[d as usize + 1] += 1;
        }
        for v in 0..num_nodes {
            offsets[v + 1] += offsets[v];
        }
        let mut cursor: Vec<u32> = offsets[..num_nodes].to_vec();
        let mut edge_order = vec![0u32; dst.len()];
        for (e, &d) in dst.iter().enumerate() {
            let slot = &mut cursor[d as usize];
            edge_order[*slot as usize] = e as u32;
            *slot += 1;
        }
        AggregationPlan {
            edge_order,
            offsets,
            num_nodes,
        }
    }

    /// Repair-free plan construction for the edge form of a CSR.  The
    /// dst-major layout [`EdgeForm::from_csr`] emits (per-destination real
    /// edges in CSR order, then the `n` self-loops) makes the grouped plan
    /// an affine function of `indptr`: destination `v` owns edge slots
    /// `indptr[v] .. indptr[v+1]` plus self-loop slot `E + v`, at offset
    /// `indptr[v] + v`.  Writing that directly is a sequential O(E + N)
    /// pass — no counting sort, no random scatter — and is bitwise equal
    /// to [`Self::build`] over the same edge form (property-tested below),
    /// which is what the incremental delta path relies on.
    pub fn for_csr_edge_form(csr: &Csr) -> AggregationPlan {
        let n = csr.num_nodes();
        let e = csr.num_edges();
        let mut offsets = vec![0u32; n + 1];
        let mut edge_order = Vec::with_capacity(e + n);
        for v in 0..n {
            offsets[v] = csr.indptr[v] + v as u32;
            edge_order.extend(csr.indptr[v]..csr.indptr[v + 1]);
            edge_order.push((e + v) as u32);
        }
        offsets[n] = (e + n) as u32;
        AggregationPlan {
            edge_order,
            offsets,
            num_nodes: n,
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    /// Edge slots whose destination is `v`, in original edge order.
    pub fn in_edges(&self, v: usize) -> &[u32] {
        &self.edge_order[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// Row-parallel Σ_e w_e · x[src_e] → out[dst_e].  Per destination the
    /// accumulation order equals the edge order (the grouping is stable),
    /// so the result is bitwise identical to the serial scatter at any
    /// thread count.
    pub fn aggregate_with(
        &self,
        x: &[f32],
        feat_dim: usize,
        src: &[i32],
        weights: &[f32],
        cfg: &ParallelConfig,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; self.num_nodes * feat_dim];
        threadpool::parallel_rows(cfg, self.num_nodes, feat_dim, &mut out, |v0, chunk| {
            for (vi, orow) in chunk.chunks_mut(feat_dim).enumerate() {
                for &e in self.in_edges(v0 + vi) {
                    let e = e as usize;
                    let w = weights[e];
                    if w == 0.0 {
                        continue;
                    }
                    let s = src[e] as usize;
                    let srow = &x[s * feat_dim..(s + 1) * feat_dim];
                    for (o, v) in orow.iter_mut().zip(srow) {
                        *o += w * v;
                    }
                }
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Csr {
        Csr::from_edges(3, &[(0, 1), (1, 0), (1, 2), (2, 1)]).unwrap()
    }

    #[test]
    fn self_loops_appended() {
        let ef = EdgeForm::from_csr(&path3());
        assert_eq!(ef.num_edges(), 4 + 3);
        // last 3 edges are self loops with sum_w == 0
        for i in 4..7 {
            assert_eq!(ef.src[i], ef.dst[i]);
            assert_eq!(ef.sum_w[i], 0.0);
        }
    }

    #[test]
    fn gcn_weights_match_formula() {
        let ef = EdgeForm::from_csr(&path3());
        // node degrees+1: d̃ = [2, 3, 2]
        // edge (1 -> 0): w = 1/sqrt(3*2)
        let idx = ef
            .src
            .iter()
            .zip(&ef.dst)
            .position(|(&s, &d)| s == 1 && d == 0)
            .unwrap();
        assert!((ef.gcn_w[idx] - 1.0 / (6.0f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn aggregate_sum_mask_skips_self_loops() {
        let ef = EdgeForm::from_csr(&path3());
        let x = vec![1.0, 2.0, 4.0]; // feat_dim = 1
        let out = ef.aggregate(&x, 1, &ef.sum_w);
        assert_eq!(out, vec![2.0, 5.0, 2.0]); // pure neighbour sums
    }

    #[test]
    fn aggregate_gcn_includes_self() {
        let ef = EdgeForm::from_csr(&path3());
        let x = vec![1.0, 1.0, 1.0];
        let out = ef.aggregate(&x, 1, &ef.gcn_w);
        // every node sees itself + neighbours with positive weights
        assert!(out.iter().all(|&v| v > 0.5));
    }

    #[test]
    fn plan_groups_every_edge_once() {
        let ef = EdgeForm::from_csr(&path3());
        let plan = ef.plan();
        let mut seen = vec![false; ef.num_edges()];
        for v in 0..plan.num_nodes() {
            for &e in plan.in_edges(v) {
                assert_eq!(ef.dst[e as usize] as usize, v);
                assert!(!seen[e as usize]);
                seen[e as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn plan_for_csr_edge_form_matches_counting_sort() {
        use crate::util::prop::{property, Gen};
        use crate::util::rng::Rng;
        property("direct plan == built plan", 40, |g: &mut Gen| {
            let n = g.usize_range(1, 80);
            let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
            let csr = crate::graph::generate::preferential_attachment(&mut rng, n, 2);
            let ef = EdgeForm::from_csr(&csr);
            assert_eq!(AggregationPlan::for_csr_edge_form(&csr), ef.plan());
        });
    }

    #[test]
    fn edge_form_delta_splice_matches_from_scratch() {
        use crate::graph::delta::GraphDelta;
        use crate::util::prop::{property, Gen};
        use crate::util::rng::Rng;
        property("edge-form splice == from_csr rebuild", 40, |g: &mut Gen| {
            let n0 = g.usize_range(2, 60);
            let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
            let csr = crate::graph::generate::preferential_attachment(&mut rng, n0, 2);
            let ef = EdgeForm::from_csr(&csr);
            let add_nodes = g.usize_range(0, 3);
            let n1 = n0 + add_nodes;
            let edges = csr.edge_list();
            let delta = GraphDelta {
                add_nodes,
                new_features: vec![],
                add_edges: (0..g.usize_range(0, 8))
                    .map(|_| (g.usize_range(0, n1) as u32, g.usize_range(0, n1) as u32))
                    .collect(),
                remove_edges: (0..g.usize_range(0, 4))
                    .map(|_| edges[g.usize_range(0, edges.len())])
                    .collect(),
            };
            let applied = delta.apply_to_csr(&csr).unwrap();
            let spliced = ef.apply_delta(&csr, &applied);
            let want = EdgeForm::from_csr(&applied.csr);
            assert_eq!(spliced.src, want.src);
            assert_eq!(spliced.dst, want.dst);
            assert_eq!(spliced.gcn_w, want.gcn_w); // bitwise: both f32 from same f64 exprs
            assert_eq!(spliced.sum_w, want.sum_w);
            assert_eq!(spliced.num_nodes, want.num_nodes);
            // and the repaired plan matches the counting-sort rebuild
            assert_eq!(
                AggregationPlan::for_csr_edge_form(&applied.csr),
                want.plan()
            );
        });
    }

    #[test]
    fn parallel_aggregate_bitwise_matches_serial_scatter() {
        use crate::util::prop::{property, Gen};
        use crate::util::rng::Rng;
        property("plan aggregate == edge scatter", 20, |g: &mut Gen| {
            let n = g.usize_range(2, 120);
            let f = g.usize_range(1, 24);
            let seed = g.usize_range(0, 1 << 30) as u64;
            let mut rng = Rng::new(seed);
            let csr = crate::graph::generate::preferential_attachment(&mut rng, n, 2);
            let ef = EdgeForm::from_csr(&csr);
            let x = g.vec_normal(n * f, 1.0);
            let cfg = ParallelConfig {
                threads: g.usize_range(1, 6),
                min_rows_per_task: g.usize_range(1, 8),
                ..ParallelConfig::serial()
            };
            let plan = ef.plan();
            for weights in [&ef.gcn_w, &ef.sum_w] {
                let serial = ef.aggregate_serial(&x, f, weights);
                let parallel = plan.aggregate_with(&x, f, &ef.src, weights, &cfg);
                assert_eq!(serial, parallel);
            }
        });
    }
}
