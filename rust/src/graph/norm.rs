//! GCN normalization and edge-form expansion.
//!
//! Builds the `(src, dst, gcn_w, sum_w)` edge arrays used by both the
//! native inference path and the PJRT artifacts (identical to
//! `python/compile/models.py::build_edges`): self-loops appended, GCN
//! weights `(d̃_s d̃_d)^{-1/2}`, and `sum_w` masking self-loops out of the
//! GIN neighbour sum.  Per the paper's Proof 2, Â itself is never
//! quantized — aggregation runs on these f32 weights / fixed-point adds.

use super::csr::Csr;

/// Edge-form graph with precomputed normalization weights.
#[derive(Debug, Clone)]
pub struct EdgeForm {
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    /// (d̃_s · d̃_d)^{-1/2} with self-loops (GCN aggregation weights)
    pub gcn_w: Vec<f32>,
    /// 1.0 for real edges, 0.0 for the appended self-loops (GIN mask)
    pub sum_w: Vec<f32>,
    pub num_nodes: usize,
}

impl EdgeForm {
    /// Expand a CSR into edge form, appending self-loops.
    pub fn from_csr(csr: &Csr) -> EdgeForm {
        let n = csr.num_nodes();
        let e = csr.num_edges();
        let mut src = Vec::with_capacity(e + n);
        let mut dst = Vec::with_capacity(e + n);
        for v in 0..n {
            for &s in csr.in_neighbors(v) {
                src.push(s as i32);
                dst.push(v as i32);
            }
        }
        for v in 0..n {
            src.push(v as i32);
            dst.push(v as i32);
        }
        // d̃ = in-degree + 1 (self loop)
        let mut dtilde = vec![1.0f64; n];
        for v in 0..n {
            dtilde[v] += csr.in_degree(v) as f64;
        }
        let gcn_w: Vec<f32> = src
            .iter()
            .zip(&dst)
            .map(|(&s, &d)| (1.0 / (dtilde[s as usize] * dtilde[d as usize]).sqrt()) as f32)
            .collect();
        let mut sum_w = vec![1.0f32; e + n];
        for w in sum_w[e..].iter_mut() {
            *w = 0.0;
        }
        EdgeForm {
            src,
            dst,
            gcn_w,
            sum_w,
            num_nodes: n,
        }
    }

    pub fn num_edges(&self) -> usize {
        self.src.len()
    }

    /// Σ_e w_e · x[src_e] → out[dst_e]   (the aggregation phase).
    pub fn aggregate(&self, x: &[f32], feat_dim: usize, weights: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.num_nodes * feat_dim];
        for ((&s, &d), &w) in self.src.iter().zip(&self.dst).zip(weights) {
            if w == 0.0 {
                continue;
            }
            let srow = &x[s as usize * feat_dim..(s as usize + 1) * feat_dim];
            let orow = &mut out[d as usize * feat_dim..(d as usize + 1) * feat_dim];
            for (o, v) in orow.iter_mut().zip(srow) {
                *o += w * v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Csr {
        Csr::from_edges(3, &[(0, 1), (1, 0), (1, 2), (2, 1)]).unwrap()
    }

    #[test]
    fn self_loops_appended() {
        let ef = EdgeForm::from_csr(&path3());
        assert_eq!(ef.num_edges(), 4 + 3);
        // last 3 edges are self loops with sum_w == 0
        for i in 4..7 {
            assert_eq!(ef.src[i], ef.dst[i]);
            assert_eq!(ef.sum_w[i], 0.0);
        }
    }

    #[test]
    fn gcn_weights_match_formula() {
        let ef = EdgeForm::from_csr(&path3());
        // node degrees+1: d̃ = [2, 3, 2]
        // edge (1 -> 0): w = 1/sqrt(3*2)
        let idx = ef
            .src
            .iter()
            .zip(&ef.dst)
            .position(|(&s, &d)| s == 1 && d == 0)
            .unwrap();
        assert!((ef.gcn_w[idx] - 1.0 / (6.0f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn aggregate_sum_mask_skips_self_loops() {
        let ef = EdgeForm::from_csr(&path3());
        let x = vec![1.0, 2.0, 4.0]; // feat_dim = 1
        let out = ef.aggregate(&x, 1, &ef.sum_w);
        assert_eq!(out, vec![2.0, 5.0, 2.0]); // pure neighbour sums
    }

    #[test]
    fn aggregate_gcn_includes_self() {
        let ef = EdgeForm::from_csr(&path3());
        let x = vec![1.0, 1.0, 1.0];
        let out = ef.aggregate(&x, 1, &ef.gcn_w);
        // every node sees itself + neighbours with positive weights
        assert!(out.iter().all(|&v| v > 0.5));
    }
}
