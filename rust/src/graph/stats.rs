//! Graph statistics used by the figure harness (Fig. 1 / Fig. 4 / Fig. 8).

use crate::util::stats::pearson;

use super::csr::Csr;

/// Histogram of in-degrees with power-of-two buckets (Fig. 8 series).
pub fn degree_histogram(csr: &Csr) -> Vec<(u32, usize)> {
    let mut buckets: Vec<usize> = Vec::new();
    for v in 0..csr.num_nodes() {
        let d = csr.in_degree(v);
        let b = if d == 0 { 0 } else { (d as f64).log2().floor() as usize + 1 };
        if b >= buckets.len() {
            buckets.resize(b + 1, 0);
        }
        buckets[b] += 1;
    }
    buckets
        .into_iter()
        .enumerate()
        .map(|(b, c)| (if b == 0 { 0 } else { 1u32 << (b - 1) }, c))
        .collect()
}

/// Group nodes by in-degree bucket and average a per-node value over each
/// group — the Fig. 1 / Fig. 4 aggregation.
pub fn mean_by_degree_group(
    csr: &Csr,
    values: &[f32],
    bounds: &[u32],
) -> Vec<(String, f64, usize)> {
    assert_eq!(values.len(), csr.num_nodes());
    let mut out = Vec::new();
    let mut lo = 0u32;
    for (i, &hi) in bounds.iter().chain(std::iter::once(&u32::MAX)).enumerate() {
        let hi = if i == bounds.len() { u32::MAX } else { hi };
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for v in 0..csr.num_nodes() {
            let d = csr.in_degree(v) as u32;
            if d >= lo && d < hi {
                sum += values[v] as f64;
                n += 1;
            }
        }
        let label = if hi == u32::MAX {
            format!("[{lo},inf)")
        } else {
            format!("[{lo},{hi})")
        };
        out.push((label, if n > 0 { sum / n as f64 } else { 0.0 }, n));
        lo = hi;
    }
    out
}

/// Pearson correlation between in-degree and a per-node value (used to
/// verify the "aggregation-aware" claim: learned bits ↔ degree).
pub fn degree_correlation(csr: &Csr, values: &[f32]) -> f64 {
    let deg: Vec<f64> = (0..csr.num_nodes())
        .map(|v| csr.in_degree(v) as f64)
        .collect();
    let vals: Vec<f64> = values.iter().map(|&v| v as f64).collect();
    pearson(&deg, &vals)
}

/// For each bitwidth 1..=8: (average in-degree of nodes using it, count) —
/// exactly the series plotted in Fig. 4.
pub fn bits_vs_degree(csr: &Csr, bits: &[u8]) -> Vec<(u8, f64, usize)> {
    assert_eq!(bits.len(), csr.num_nodes());
    (1u8..=8)
        .map(|b| {
            let mut sum = 0.0f64;
            let mut n = 0usize;
            for v in 0..csr.num_nodes() {
                if bits[v] == b {
                    sum += csr.in_degree(v) as f64;
                    n += 1;
                }
            }
            (b, if n > 0 { sum / n as f64 } else { 0.0 }, n)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ba(n: usize) -> Csr {
        let mut rng = Rng::new(0);
        crate::graph::generate::preferential_attachment(&mut rng, n, 2)
    }

    #[test]
    fn degree_histogram_counts_all_nodes() {
        let g = ba(500);
        let h = degree_histogram(&g);
        let total: usize = h.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 500);
        // power law: bucket counts decay with degree
        assert!(h[1].1 + h[2].1 > h.last().unwrap().1 * 3);
    }

    #[test]
    fn mean_by_degree_group_partition() {
        let g = ba(300);
        let vals: Vec<f32> = (0..300).map(|v| g.in_degree(v) as f32).collect();
        let groups = mean_by_degree_group(&g, &vals, &[2, 4, 8, 16]);
        let total: usize = groups.iter().map(|&(_, _, n)| n).sum();
        assert_eq!(total, 300);
        // value == degree, so group means must be increasing
        let means: Vec<f64> = groups.iter().filter(|g| g.2 > 0).map(|g| g.1).collect();
        for w in means.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn degree_correlation_of_degree_itself_is_one() {
        let g = ba(200);
        let vals: Vec<f32> = (0..200).map(|v| g.in_degree(v) as f32).collect();
        assert!((degree_correlation(&g, &vals) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bits_vs_degree_grouping() {
        let g = ba(200);
        // assign high bits to high-degree nodes artificially
        let med = {
            let mut d = g.in_degrees();
            d.sort_unstable();
            d[100]
        };
        let bits: Vec<u8> = (0..200)
            .map(|v| if g.in_degree(v) as u32 > med { 8 } else { 2 })
            .collect();
        let rows = bits_vs_degree(&g, &bits);
        let low = rows.iter().find(|r| r.0 == 2).unwrap();
        let high = rows.iter().find(|r| r.0 == 8).unwrap();
        assert!(high.1 > low.1);
    }
}
