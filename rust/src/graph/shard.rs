//! Sharded resident graphs: degree-aware partitioning + halo exchange.
//!
//! A single resident CSR behind one lock is the scaling ceiling of the
//! serving path: every frontier recompute serializes on one
//! [`AggregationPlan`].  This module partitions the resident graph into
//! `S` shards — one **owner** per node, chosen by a degree-aware
//! partitioner that balances aggregation work (Σ d̃ per shard) rather than
//! node counts — and gives each shard a self-contained local view:
//!
//! * `owned` — the global ids this shard computes output rows for
//!   (ascending, so a shard's output block scatters back with one walk),
//! * `halo` — the *remote* in-neighbours whose feature rows must be
//!   mirrored into the shard before each layer (the halo exchange),
//! * local `(src, gcn_w, sum_w)` edge arrays in the **same per-destination
//!   order** as the global [`EdgeForm`] (real CSR edges first, then the
//!   self-loop), with `src` remapped to mirror-local indices,
//! * a per-shard [`AggregationPlan`] over the local destination ids.
//!
//! Because every output row has exactly one owner and the per-destination
//! edge order is preserved, a shard-parallel forward that mirrors halo
//! rows bit-exactly accumulates each row in the *identical* f32 order as
//! the single-shard prepared path — bitwise equality is by construction,
//! and `rust/tests/shard_parity.rs` property-tests it.
//!
//! The in-process halo exchange mirrors f32 activation rows
//! ([`ShardLocal::gather_mirror`] / [`ShardLocal::halo_bytes`] account at
//! f32 width).  A²Q is what would make a *distributed* deployment's
//! at-rest shard state cheap — most nodes carry low aggregation values
//! and earn few bits, and the integer path already stores each shard's
//! quantized hidden map as a packed slab
//! (`quant::pack::pack_rows_subset`, a few bits per feature).

use crate::error::{Error, Result};

use super::csr::Csr;
use super::norm::{AggregationPlan, EdgeForm};

/// Node → shard assignment produced by the degree-aware partitioner.
#[derive(Debug, Clone)]
pub struct ShardPartition {
    /// per node: owning shard
    pub owner: Vec<u32>,
    /// per shard: owned global node ids, ascending
    pub owned: Vec<Vec<u32>>,
    /// per shard: Σ (d̃ = in_degree + 1) over owned nodes (balance metric)
    pub load: Vec<u64>,
}

impl ShardPartition {
    /// Degree-aware greedy partition (LPT over d̃ = in-degree + 1): nodes
    /// are placed heaviest-first onto the least-loaded shard, so hub nodes
    /// — which dominate aggregation cost on power-law graphs — spread
    /// across shards instead of piling onto one.  Deterministic: ties
    /// break by node id (stable sort) and by lowest shard id.
    pub fn degree_aware(csr: &Csr, num_shards: usize) -> ShardPartition {
        let s = num_shards.max(1);
        let n = csr.num_nodes();
        let mut by_degree: Vec<u32> = (0..n as u32).collect();
        by_degree.sort_by_key(|&v| std::cmp::Reverse(csr.in_degree(v as usize)));
        let mut owner = vec![0u32; n];
        let mut load = vec![0u64; s];
        for &v in &by_degree {
            let mut best = 0usize;
            for k in 1..s {
                if load[k] < load[best] {
                    best = k;
                }
            }
            owner[v as usize] = best as u32;
            load[best] += csr.in_degree(v as usize) as u64 + 1;
        }
        let mut owned = vec![Vec::new(); s];
        for (v, &o) in owner.iter().enumerate() {
            owned[o as usize].push(v as u32);
        }
        ShardPartition { owner, owned, load }
    }

    pub fn num_shards(&self) -> usize {
        self.owned.len()
    }

    /// Extend the partition with `add_nodes` appended nodes, each assigned
    /// to the currently least-loaded shard (deterministic: lowest shard id
    /// wins ties).  Returns the shards that received nodes.
    pub fn assign_appended(&mut self, add_nodes: usize) -> Vec<usize> {
        let mut touched = Vec::new();
        for _ in 0..add_nodes {
            let mut best = 0usize;
            for k in 1..self.load.len() {
                if self.load[k] < self.load[best] {
                    best = k;
                }
            }
            let v = self.owner.len() as u32;
            self.owner.push(best as u32);
            self.owned[best].push(v);
            self.load[best] += 1;
            if !touched.contains(&best) {
                touched.push(best);
            }
        }
        touched
    }
}

/// One shard's self-contained local view of the resident graph.
#[derive(Debug, Clone)]
pub struct ShardLocal {
    /// global ids of owned nodes, ascending — output rows in this order
    pub owned: Vec<u32>,
    /// global ids of remote in-neighbours, ascending (disjoint from
    /// `owned`) — their rows occupy mirror slots `owned.len()..`
    pub halo: Vec<u32>,
    /// per-edge source as a mirror-local index (owned block first, then
    /// the halo block)
    pub src: Vec<i32>,
    /// per-edge destination as an owned-local index (what the plan groups)
    pub dst: Vec<i32>,
    /// GCN normalization weights, copied from the global edge form
    pub gcn_w: Vec<f32>,
    /// GIN sum mask (1.0 real edge, 0.0 self-loop)
    pub sum_w: Vec<f32>,
    /// destination-grouped plan over the local edges
    pub plan: AggregationPlan,
    /// edges whose source is a halo mirror (cross-shard edges)
    pub halo_edges: usize,
}

impl ShardLocal {
    /// Mirror row count (owned + halo).
    pub fn mirror_rows(&self) -> usize {
        self.owned.len() + self.halo.len()
    }

    /// Mirror-local index of a global id (must be owned or halo).
    pub fn local_index(&self, gid: u32) -> usize {
        match self.owned.binary_search(&gid) {
            Ok(i) => i,
            Err(_) => {
                self.owned.len()
                    + self.halo.binary_search(&gid).expect("gid owned or halo")
            }
        }
    }

    /// Gather the mirror feature block for this shard out of the global
    /// `[N, cols]` activation matrix `x` — the **halo exchange**: the
    /// owned block is a local copy, the halo block is the cross-shard
    /// traffic.  Returns the mirror buffer (row-major, `mirror_rows()` ×
    /// `cols`).
    pub fn gather_mirror(&self, x: &[f32], cols: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.mirror_rows() * cols);
        for &gid in self.owned.iter().chain(&self.halo) {
            let g = gid as usize;
            out.extend_from_slice(&x[g * cols..(g + 1) * cols]);
        }
        out
    }

    /// Bytes a distributed runtime would move for one halo exchange of
    /// f32 rows at this width.
    pub fn halo_bytes(&self, cols: usize) -> usize {
        self.halo.len() * cols * 4
    }

    /// Build shard `s`'s local view from the resident CSR + its edge form
    /// (which must be `EdgeForm::from_csr(csr)`-shaped: dst-major real
    /// edges, then the `n` self-loops).  Per owned destination the local
    /// edge order is real CSR edges then the self-loop — exactly the
    /// per-destination order of the global plan, which is what makes the
    /// sharded aggregation bitwise-equal to the single-shard gather.
    pub fn build(csr: &Csr, ef: &EdgeForm, owner: &[u32], s: u32, owned: Vec<u32>) -> ShardLocal {
        debug_assert_eq!(ef.num_nodes, csr.num_nodes());
        debug_assert!(owned.windows(2).all(|w| w[0] < w[1]));
        let real_w = ef.gcn_w_real(csr.num_edges());
        let self_w = ef.gcn_w_self(csr.num_edges());
        // halo: sorted, deduplicated remote sources
        let mut halo: Vec<u32> = Vec::new();
        for &v in &owned {
            for &src in csr.in_neighbors(v as usize) {
                if owner[src as usize] != s {
                    halo.push(src);
                }
            }
        }
        halo.sort_unstable();
        halo.dedup();

        let n_local_edges: usize =
            owned.iter().map(|&v| csr.in_degree(v as usize) + 1).sum();
        let mut src = Vec::with_capacity(n_local_edges);
        let mut dst = Vec::with_capacity(n_local_edges);
        let mut gcn_w = Vec::with_capacity(n_local_edges);
        let mut sum_w = Vec::with_capacity(n_local_edges);
        let mut halo_edges = 0usize;
        let local = |gid: u32| -> i32 {
            match owned.binary_search(&gid) {
                Ok(i) => i as i32,
                Err(_) => {
                    (owned.len() + halo.binary_search(&gid).expect("halo covers remotes"))
                        as i32
                }
            }
        };
        for (li, &v) in owned.iter().enumerate() {
            let vu = v as usize;
            let base = csr.indptr[vu] as usize;
            for (k, &u) in csr.in_neighbors(vu).iter().enumerate() {
                if owner[u as usize] != s {
                    halo_edges += 1;
                }
                src.push(local(u));
                dst.push(li as i32);
                gcn_w.push(real_w[base + k]);
                sum_w.push(1.0);
            }
            // the self-loop (sum_w 0.0 masks it out of the GIN sum)
            src.push(li as i32);
            dst.push(li as i32);
            gcn_w.push(self_w[vu]);
            sum_w.push(0.0);
        }
        let plan = AggregationPlan::build(&dst, owned.len());
        ShardLocal {
            owned,
            halo,
            src,
            dst,
            gcn_w,
            sum_w,
            plan,
            halo_edges,
        }
    }
}

/// The resident graph partitioned into shards, ready for the
/// shard-parallel forward (`gnn::forward_fp_sharded` /
/// `gnn::forward_int_sharded`).
#[derive(Debug, Clone)]
pub struct ShardedGraph {
    pub partition: ShardPartition,
    pub shards: Vec<ShardLocal>,
    pub num_nodes: usize,
}

/// Aggregate halo statistics (serving metrics / bench output).
#[derive(Debug, Clone, Copy, Default)]
pub struct HaloStats {
    /// Σ over shards of mirrored remote nodes
    pub halo_nodes: usize,
    /// Σ over shards of cross-shard edges
    pub halo_edges: usize,
    /// Σ over shards of local edges (incl. self-loops)
    pub local_edges: usize,
}

impl HaloStats {
    /// Fraction of edges that cross shards (0 for S = 1).
    pub fn halo_fraction(&self) -> f64 {
        if self.local_edges == 0 {
            0.0
        } else {
            self.halo_edges as f64 / self.local_edges as f64
        }
    }
}

impl ShardedGraph {
    /// Partition `csr` into `num_shards` shards with the degree-aware
    /// partitioner and build every local view.  `ef` must be
    /// `EdgeForm::from_csr(csr)` (validated by shape).
    pub fn build(csr: &Csr, ef: &EdgeForm, num_shards: usize) -> Result<ShardedGraph> {
        if ef.num_nodes != csr.num_nodes()
            || ef.num_edges() != csr.num_edges() + csr.num_nodes()
        {
            return Err(Error::shape(
                "ShardedGraph::build: edge form does not match the CSR",
            ));
        }
        let partition = ShardPartition::degree_aware(csr, num_shards);
        let shards: Vec<ShardLocal> = (0..partition.num_shards())
            .map(|s| {
                ShardLocal::build(csr, ef, &partition.owner, s as u32, partition.owned[s].clone())
            })
            .collect();
        Ok(ShardedGraph {
            partition,
            shards,
            num_nodes: csr.num_nodes(),
        })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Owner shard + position within its owned block for a global id.
    pub fn locate(&self, gid: u32) -> (usize, usize) {
        let s = self.partition.owner[gid as usize] as usize;
        let pos = self.shards[s]
            .owned
            .binary_search(&gid)
            .expect("owner lists its nodes");
        (s, pos)
    }

    pub fn halo_stats(&self) -> HaloStats {
        let mut st = HaloStats::default();
        for sh in &self.shards {
            st.halo_nodes += sh.halo.len();
            st.halo_edges += sh.halo_edges;
            st.local_edges += sh.src.len();
        }
        st
    }

    /// Apply a committed delta: `applied_csr`/`ef` are the **post-delta**
    /// structures, `add_nodes` how many nodes the delta appended,
    /// `row_changed`/`deg_changed` the per-node dirty masks from
    /// [`super::delta::DeltaApplied`].  Appended nodes are assigned to the
    /// least-loaded shards; only shards whose owned rows or halo mirrors
    /// are affected get their local view rebuilt — everything else is
    /// carried over verbatim.  Returns the rebuilt shard ids.
    pub fn apply_delta(
        &mut self,
        applied_csr: &Csr,
        ef: &EdgeForm,
        add_nodes: usize,
        row_changed: &[bool],
        deg_changed: &[bool],
    ) -> Vec<usize> {
        let new_shards = self.partition.assign_appended(add_nodes);
        let s_count = self.partition.num_shards();
        let mut dirty = vec![false; s_count];
        for s in new_shards {
            dirty[s] = true;
        }
        // a shard is affected when it owns a structurally-changed row, or
        // when any node it mirrors (or owns) changed degree — the d̃ move
        // reprices that node's gcn_w in every local copy
        for (v, (&rc, &dc)) in row_changed.iter().zip(deg_changed).enumerate() {
            if rc {
                dirty[self.partition.owner[v] as usize] = true;
            }
            if dc {
                for (s, sh) in self.shards.iter().enumerate() {
                    if !dirty[s]
                        && (sh.halo.binary_search(&(v as u32)).is_ok()
                            || self.partition.owner[v] as usize == s)
                    {
                        dirty[s] = true;
                    }
                }
            }
        }
        let dirty_ids: Vec<usize> = dirty
            .iter()
            .enumerate()
            .filter_map(|(s, &d)| d.then_some(s))
            .collect();
        // rebuild in place: untouched shards keep their existing local
        // views (no clone), so a small delta costs O(dirty shards' edges),
        // not O(total edges)
        for &s in &dirty_ids {
            self.shards[s] = ShardLocal::build(
                applied_csr,
                ef,
                &self.partition.owner,
                s as u32,
                self.partition.owned[s].clone(),
            );
        }
        self.num_nodes = applied_csr.num_nodes();
        dirty_ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::delta::GraphDelta;
    use crate::util::prop::{property, Gen};
    use crate::util::rng::Rng;

    fn random_graph(g: &mut Gen, n: usize) -> Csr {
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        crate::graph::generate::preferential_attachment(&mut rng, n, 2)
    }

    #[test]
    fn partition_covers_every_node_exactly_once() {
        property("partition is a partition", 25, |g: &mut Gen| {
            let n = g.usize_range(2, 120);
            let s = g.usize_range(1, 9);
            let csr = random_graph(g, n);
            let p = ShardPartition::degree_aware(&csr, s);
            assert_eq!(p.num_shards(), s);
            let mut seen = vec![false; n];
            for (shard, owned) in p.owned.iter().enumerate() {
                assert!(owned.windows(2).all(|w| w[0] < w[1]), "owned sorted");
                for &v in owned {
                    assert_eq!(p.owner[v as usize] as usize, shard);
                    assert!(!seen[v as usize]);
                    seen[v as usize] = true;
                }
            }
            assert!(seen.iter().all(|&b| b));
        });
    }

    #[test]
    fn degree_aware_balances_hubby_graphs() {
        let mut rng = Rng::new(5);
        let csr = crate::graph::generate::preferential_attachment(&mut rng, 2000, 2);
        let p = ShardPartition::degree_aware(&csr, 4);
        let max = *p.load.iter().max().unwrap() as f64;
        let min = *p.load.iter().min().unwrap() as f64;
        assert!(
            max / min.max(1.0) < 1.2,
            "degree-aware loads should be near-balanced: {:?}",
            p.load
        );
    }

    #[test]
    fn shard_edges_reproduce_the_global_edge_form() {
        property("shard locals cover the edge form", 20, |g: &mut Gen| {
            let n = g.usize_range(2, 90);
            let s = g.usize_range(1, 5);
            let csr = random_graph(g, n);
            let ef = EdgeForm::from_csr(&csr);
            let sg = ShardedGraph::build(&csr, &ef, s).unwrap();
            let mut covered = 0usize;
            for sh in &sg.shards {
                for (e, (&ld, &ls)) in sh.dst.iter().zip(&sh.src).enumerate() {
                    let gd = sh.owned[ld as usize];
                    let gs = if (ls as usize) < sh.owned.len() {
                        sh.owned[ls as usize]
                    } else {
                        sh.halo[ls as usize - sh.owned.len()]
                    };
                    if sh.sum_w[e] == 0.0 {
                        assert_eq!(gs, gd, "self-loop");
                        // self-loop weight matches the global trailing block
                        assert_eq!(
                            sh.gcn_w[e],
                            ef.gcn_w_self(csr.num_edges())[gd as usize]
                        );
                    } else {
                        // real edge exists in the CSR with the same weight
                        let row = csr.in_neighbors(gd as usize);
                        let k = row.binary_search(&gs).expect("edge in csr");
                        assert_eq!(
                            sh.gcn_w[e],
                            ef.gcn_w_real(csr.num_edges())
                                [csr.indptr[gd as usize] as usize + k]
                        );
                    }
                    covered += 1;
                }
            }
            assert_eq!(covered, ef.num_edges(), "every edge owned exactly once");
        });
    }

    #[test]
    fn gather_mirror_copies_rows_bit_exactly() {
        let mut g = Gen::new(17);
        let csr = random_graph(&mut g, 40);
        let ef = EdgeForm::from_csr(&csr);
        let sg = ShardedGraph::build(&csr, &ef, 3).unwrap();
        let cols = 5;
        let x = g.vec_normal(40 * cols, 1.0);
        for sh in &sg.shards {
            let mirror = sh.gather_mirror(&x, cols);
            assert_eq!(mirror.len(), sh.mirror_rows() * cols);
            for (li, &gid) in sh.owned.iter().chain(&sh.halo).enumerate() {
                assert_eq!(
                    &mirror[li * cols..(li + 1) * cols],
                    &x[gid as usize * cols..(gid as usize + 1) * cols]
                );
                assert_eq!(sh.local_index(gid), li);
            }
            assert_eq!(sh.halo_bytes(cols), sh.halo.len() * cols * 4);
        }
        let stats = sg.halo_stats();
        assert_eq!(stats.local_edges, ef.num_edges());
        assert!(stats.halo_fraction() >= 0.0 && stats.halo_fraction() <= 1.0);
    }

    #[test]
    fn single_shard_has_no_halo() {
        let mut g = Gen::new(3);
        let csr = random_graph(&mut g, 30);
        let ef = EdgeForm::from_csr(&csr);
        let sg = ShardedGraph::build(&csr, &ef, 1).unwrap();
        assert_eq!(sg.shards[0].halo.len(), 0);
        assert_eq!(sg.halo_stats().halo_edges, 0);
        assert_eq!(sg.halo_stats().halo_fraction(), 0.0);
    }

    #[test]
    fn delta_rebuilds_only_affected_shards() {
        property("delta touches owning shards only", 15, |g: &mut Gen| {
            let n = g.usize_range(8, 70);
            let s = g.usize_range(1, 5);
            let csr = random_graph(g, n);
            let ef = EdgeForm::from_csr(&csr);
            let mut sg = ShardedGraph::build(&csr, &ef, s).unwrap();
            let before = sg.shards.clone();

            let add_nodes = g.usize_range(0, 3);
            let n1 = n + add_nodes;
            let delta = GraphDelta {
                add_nodes,
                new_features: vec![],
                add_edges: (0..g.usize_range(0, 6))
                    .map(|_| (g.usize_range(0, n1) as u32, g.usize_range(0, n1) as u32))
                    .collect(),
                remove_edges: vec![],
            };
            let applied = delta.apply_to_csr(&csr).unwrap();
            let ef2 = ef.apply_delta(&csr, &applied);
            let rebuilt = sg.apply_delta(
                &applied.csr,
                &ef2,
                add_nodes,
                &applied.row_changed,
                &applied.deg_changed,
            );

            // every shard local now equals a from-scratch build over the
            // post-delta graph (untouched shards by carry-over)
            let fresh = ShardedGraph::build(&applied.csr, &ef2, s).unwrap();
            // partitions may differ for appended nodes only if loads tie
            // differently — compare against a rebuild over *this* partition
            for (si, sh) in sg.shards.iter().enumerate() {
                let want = ShardLocal::build(
                    &applied.csr,
                    &ef2,
                    &sg.partition.owner,
                    si as u32,
                    sg.partition.owned[si].clone(),
                );
                assert_eq!(sh.owned, want.owned, "shard {si} owned");
                assert_eq!(sh.halo, want.halo, "shard {si} halo");
                assert_eq!(sh.src, want.src, "shard {si} src");
                assert_eq!(sh.gcn_w, want.gcn_w, "shard {si} gcn_w");
                assert_eq!(sh.sum_w, want.sum_w, "shard {si} sum_w");
                // untouched shards were carried over verbatim
                if !rebuilt.contains(&si) {
                    assert_eq!(sh.src, before[si].src, "shard {si} should be untouched");
                }
            }
            assert_eq!(fresh.num_nodes, sg.num_nodes);
        });
    }
}
