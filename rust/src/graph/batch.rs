//! Block-diagonal batching of small graphs (graph-level serving).
//!
//! Mirrors `python/compile/models.py::pad_graph_batch`: the coordinator's
//! dynamic batcher packs several request graphs into one fixed-capacity
//! batch (static shapes for the AOT executable).  Padding nodes route to a
//! dummy segment `G` and padding edges carry zero weight, so readout over
//! real segments is exact.

use crate::error::{Error, Result};

use super::io::SmallGraph;

/// A packed batch matching the AOT executable's input shapes.
#[derive(Debug, Clone)]
pub struct GraphBatch {
    pub features: Vec<f32>, // [cap_nodes * feat_dim]
    pub src: Vec<i32>,      // [cap_edges]
    pub dst: Vec<i32>,
    pub gcn_w: Vec<f32>,
    pub sum_w: Vec<f32>,
    pub node2graph: Vec<i32>, // [cap_nodes]
    pub node_mask: Vec<f32>,
    pub cap_nodes: usize,
    pub cap_edges: usize,
    pub cap_graphs: usize,
    pub num_graphs: usize,
    pub feat_dim: usize,
}

impl GraphBatch {
    /// Pack `graphs` into a batch with the given static capacities.
    pub fn pack(
        graphs: &[&SmallGraph],
        feat_dim: usize,
        cap_nodes: usize,
        cap_edges: usize,
        cap_graphs: usize,
    ) -> Result<GraphBatch> {
        if graphs.len() > cap_graphs {
            return Err(Error::shape(format!(
                "batch of {} graphs exceeds capacity {}",
                graphs.len(),
                cap_graphs
            )));
        }
        let total_nodes: usize = graphs.iter().map(|g| g.num_nodes()).sum();
        let total_edges: usize = graphs
            .iter()
            .map(|g| g.csr.num_edges() + g.num_nodes())
            .sum();
        if total_nodes > cap_nodes || total_edges > cap_edges {
            return Err(Error::shape(format!(
                "batch needs {total_nodes} nodes / {total_edges} edges, capacity \
                 {cap_nodes}/{cap_edges}"
            )));
        }

        let mut features = vec![0.0f32; cap_nodes * feat_dim];
        let mut node2graph = vec![graphs.len() as i32; cap_nodes];
        let mut node_mask = vec![0.0f32; cap_nodes];
        let mut src = Vec::with_capacity(cap_edges);
        let mut dst = Vec::with_capacity(cap_edges);
        let mut gcn_w = Vec::with_capacity(cap_edges);
        let mut sum_w = Vec::with_capacity(cap_edges);

        let mut off = 0usize;
        for (gi, g) in graphs.iter().enumerate() {
            let n = g.num_nodes();
            features[off * feat_dim..(off + n) * feat_dim].copy_from_slice(&g.features);
            for v in 0..n {
                node2graph[off + v] = gi as i32;
                node_mask[off + v] = 1.0;
            }
            // d̃ = in-degree + 1
            let deg: Vec<f64> = (0..n).map(|v| g.csr.in_degree(v) as f64 + 1.0).collect();
            for v in 0..n {
                for &s in g.csr.in_neighbors(v) {
                    src.push((off + s as usize) as i32);
                    dst.push((off + v) as i32);
                    gcn_w.push((1.0 / (deg[s as usize] * deg[v]).sqrt()) as f32);
                    sum_w.push(1.0);
                }
            }
            for v in 0..n {
                src.push((off + v) as i32);
                dst.push((off + v) as i32);
                gcn_w.push((1.0 / deg[v]) as f32);
                sum_w.push(0.0);
            }
            off += n;
        }
        // pad edges: self-edges on node 0 with zero weight
        while src.len() < cap_edges {
            src.push(0);
            dst.push(0);
            gcn_w.push(0.0);
            sum_w.push(0.0);
        }

        Ok(GraphBatch {
            features,
            src,
            dst,
            gcn_w,
            sum_w,
            node2graph,
            node_mask,
            cap_nodes,
            cap_edges,
            cap_graphs,
            num_graphs: graphs.len(),
            feat_dim,
        })
    }

    /// True node count (non-padding).
    pub fn real_nodes(&self) -> usize {
        self.node_mask.iter().filter(|&&m| m > 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::util::prop::{property, Gen};
    use crate::util::rng::Rng;

    fn tiny_graph(n: usize, seed: u64) -> SmallGraph {
        let mut rng = Rng::new(seed);
        let csr = crate::graph::generate::molecule(&mut rng, n, 1);
        let nn = csr.num_nodes();
        SmallGraph {
            csr,
            features: (0..nn * 3).map(|i| i as f32 * 0.1).collect(),
            target_class: 0,
            target_value: 0.0,
        }
    }

    #[test]
    fn pack_basic_layout() {
        let g1 = tiny_graph(5, 0);
        let g2 = tiny_graph(7, 1);
        let b = GraphBatch::pack(&[&g1, &g2], 3, 20, 200, 4).unwrap();
        assert_eq!(b.real_nodes(), 12);
        assert_eq!(b.node2graph[..5], [0, 0, 0, 0, 0]);
        assert_eq!(b.node2graph[5..12], [1; 7]);
        assert_eq!(b.node2graph[12], 2); // dummy segment
        assert_eq!(b.src.len(), 200);
    }

    #[test]
    fn capacity_overflow_rejected() {
        let g1 = tiny_graph(30, 0);
        assert!(GraphBatch::pack(&[&g1], 3, 10, 100, 2).is_err());
        assert!(GraphBatch::pack(&[&g1, &g1, &g1], 3, 1000, 10_000, 2).is_err());
    }

    #[test]
    fn no_cross_graph_edges_property() {
        property("block-diagonal batching", 25, |g: &mut Gen| {
            let k = g.usize_range(1, 5);
            let graphs: Vec<SmallGraph> = (0..k)
                .map(|i| tiny_graph(g.usize_range(3, 15), i as u64))
                .collect();
            let refs: Vec<&SmallGraph> = graphs.iter().collect();
            let total_n: usize = graphs.iter().map(|x| x.num_nodes()).sum();
            let b = GraphBatch::pack(&refs, 3, total_n + 8, 4096, 8).unwrap();
            for ((&s, &d), &w) in b.src.iter().zip(&b.dst).zip(&b.gcn_w) {
                if w > 0.0 {
                    assert_eq!(b.node2graph[s as usize], b.node2graph[d as usize]);
                }
            }
            // feature block copied intact for each graph
            let mut off = 0;
            for gr in &graphs {
                let n = gr.num_nodes();
                assert_eq!(
                    &b.features[off * 3..(off + n) * 3],
                    gr.features.as_slice()
                );
                off += n;
            }
        });
    }

    #[test]
    fn empty_batch_is_all_padding() {
        let b = GraphBatch::pack(&[], 3, 4, 8, 2).unwrap();
        assert_eq!(b.real_nodes(), 0);
        assert!(b.gcn_w.iter().all(|&w| w == 0.0));
    }

    #[test]
    fn gcn_weights_match_single_graph_form() {
        // packing one graph must reproduce EdgeForm's weights
        let g1 = tiny_graph(6, 2);
        let n = g1.num_nodes();
        let e = g1.csr.num_edges();
        let b = GraphBatch::pack(&[&g1], 3, n, e + n, 1).unwrap();
        let ef = crate::graph::norm::EdgeForm::from_csr(&g1.csr);
        for i in 0..e + n {
            assert_eq!(b.src[i], ef.src[i]);
            assert_eq!(b.dst[i], ef.dst[i]);
            assert!((b.gcn_w[i] - ef.gcn_w[i]).abs() < 1e-6);
        }
        let _ = Csr::from_edges(2, &[(0, 1)]); // keep import used
    }
}
