//! Compressed Sparse Row adjacency (incoming edges, dst-major).

use crate::error::{Error, Result};

/// CSR over incoming edges: `indices[indptr[v]..indptr[v+1]]` are the
/// *sources* of edges arriving at node `v`.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
}

impl Csr {
    pub fn num_nodes(&self) -> usize {
        self.indptr.len().saturating_sub(1)
    }

    pub fn num_edges(&self) -> usize {
        self.indices.len()
    }

    /// Incoming neighbours (edge sources) of `v`.
    pub fn in_neighbors(&self, v: usize) -> &[u32] {
        &self.indices[self.indptr[v] as usize..self.indptr[v + 1] as usize]
    }

    pub fn in_degree(&self, v: usize) -> usize {
        (self.indptr[v + 1] - self.indptr[v]) as usize
    }

    pub fn in_degrees(&self) -> Vec<u32> {
        (0..self.num_nodes())
            .map(|v| self.in_degree(v) as u32)
            .collect()
    }

    /// Build from an edge list (src, dst), deduplicating parallel edges.
    pub fn from_edges(num_nodes: usize, edges: &[(u32, u32)]) -> Result<Csr> {
        let n = num_nodes;
        for &(s, d) in edges {
            if s as usize >= n || d as usize >= n {
                return Err(Error::dataset(format!(
                    "edge ({s},{d}) out of range for {n} nodes"
                )));
            }
        }
        // sort by (dst, src) then dedup
        let mut keyed: Vec<u64> = edges
            .iter()
            .map(|&(s, d)| (d as u64) << 32 | s as u64)
            .collect();
        keyed.sort_unstable();
        keyed.dedup();
        let mut indptr = vec![0u32; n + 1];
        let mut indices = Vec::with_capacity(keyed.len());
        for &k in &keyed {
            let d = (k >> 32) as usize;
            indptr[d + 1] += 1;
            indices.push((k & 0xffff_ffff) as u32);
        }
        for v in 0..n {
            indptr[v + 1] += indptr[v];
        }
        Ok(Csr { indptr, indices })
    }

    /// Expand to a (src, dst) edge list in dst-major order.
    pub fn edge_list(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.num_edges());
        for v in 0..self.num_nodes() {
            for &s in self.in_neighbors(v) {
                out.push((s, v as u32));
            }
        }
        out
    }

    /// Structural validation (used after IO).
    pub fn validate(&self) -> Result<()> {
        let n = self.num_nodes();
        if self.indptr.is_empty() || self.indptr[0] != 0 {
            return Err(Error::dataset("csr: indptr must start at 0"));
        }
        if *self.indptr.last().unwrap() as usize != self.indices.len() {
            return Err(Error::dataset("csr: indptr end != nnz"));
        }
        for w in self.indptr.windows(2) {
            if w[1] < w[0] {
                return Err(Error::dataset("csr: indptr not monotone"));
            }
        }
        if self.indices.iter().any(|&s| s as usize >= n) {
            return Err(Error::dataset("csr: index out of range"));
        }
        Ok(())
    }

    /// Whether the graph is symmetric (u→v implies v→u).  The synthetic
    /// datasets are undirected, so this holds for all of them.
    pub fn is_symmetric(&self) -> bool {
        let mut edges: Vec<(u32, u32)> = self.edge_list();
        edges.sort_unstable();
        self.edge_list()
            .iter()
            .all(|&(s, d)| edges.binary_search(&(d, s)).is_ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{property, Gen};

    fn path3() -> Csr {
        // 0 <-> 1 <-> 2
        Csr::from_edges(3, &[(0, 1), (1, 0), (1, 2), (2, 1)]).unwrap()
    }

    #[test]
    fn from_edges_basic() {
        let g = path3();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.in_neighbors(1), &[0, 2]);
        assert_eq!(g.in_degree(0), 1);
        g.validate().unwrap();
        assert!(g.is_symmetric());
    }

    #[test]
    fn dedups_parallel_edges() {
        let g = Csr::from_edges(2, &[(0, 1), (0, 1), (0, 1)]).unwrap();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(Csr::from_edges(2, &[(0, 5)]).is_err());
    }

    #[test]
    fn edge_list_roundtrip_property() {
        property("csr edge_list roundtrip", 50, |g: &mut Gen| {
            let n = g.usize_range(1, 40);
            let m = g.usize_range(0, 120);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (g.usize_range(0, n) as u32, g.usize_range(0, n) as u32))
                .collect();
            let csr = Csr::from_edges(n, &edges).unwrap();
            csr.validate().unwrap();
            let back = Csr::from_edges(n, &csr.edge_list()).unwrap();
            assert_eq!(csr, back);
            // degree sum == edge count
            let total: usize = (0..n).map(|v| csr.in_degree(v)).sum();
            assert_eq!(total, csr.num_edges());
        });
    }
}
