//! Graph substrate: CSR storage, generators, normalization, batching, IO.
//!
//! The CSR is **incoming-edge** oriented (dst-major), matching the python
//! serializer (`python/compile/datasets.py`) and the aggregation direction
//! of the MPNN forms in the paper's Table 4.

pub mod batch;
pub mod csr;
pub mod delta;
pub mod generate;
pub mod io;
pub mod norm;
pub mod shard;
pub mod stats;

pub use batch::GraphBatch;
pub use csr::Csr;
pub use delta::{dirty_frontier, DeltaApplied, GraphDelta};
pub use io::{load_dataset, Dataset, GraphSet, NodeData};
pub use shard::{HaloStats, ShardLocal, ShardPartition, ShardedGraph};
