//! Synthetic graph generators (rust-native).
//!
//! The python side generates the *training* datasets; these generators feed
//! the property tests, the benches and the coordinator load generator with
//! structurally matching graphs without reading artifacts.  Same families:
//! preferential attachment (power-law in-degree), Q/A vs discussion thread
//! shapes (REDDIT-B analogue), k-NN "superpixel" grids, molecule-like trees.

use crate::util::rng::Rng;

use super::csr::Csr;

/// Barabási–Albert-style preferential attachment; undirected (both edge
/// directions present).  `m` = edges per new node.
pub fn preferential_attachment(rng: &mut Rng, n: usize, m: usize) -> Csr {
    let m = m.max(1);
    let seed_n = (m + 1).max(3).min(n);
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut endpoints: Vec<u32> = Vec::new();
    for i in 0..seed_n {
        for j in 0..i {
            edges.push((i as u32, j as u32));
            endpoints.push(i as u32);
            endpoints.push(j as u32);
        }
    }
    for v in seed_n..n {
        let mut targets = Vec::with_capacity(m);
        let mut attempts = 0;
        while targets.len() < m && attempts < 50 * m {
            attempts += 1;
            let u = endpoints[rng.below(endpoints.len())];
            if u as usize != v && !targets.contains(&u) {
                targets.push(u);
            }
        }
        for &u in &targets {
            edges.push((v as u32, u));
            endpoints.push(v as u32);
            endpoints.push(u);
        }
    }
    let mut both = Vec::with_capacity(edges.len() * 2);
    for &(s, d) in &edges {
        both.push((s, d));
        both.push((d, s));
    }
    Csr::from_edges(n, &both).expect("generator produces valid edges")
}

/// Q/A-thread shaped graph (hubby; REDDIT-B class 0 analogue).
pub fn qa_thread(rng: &mut Rng, n: usize) -> Csr {
    let n = n.max(4);
    let hubs = (n / 40).max(2);
    let mut edges = Vec::new();
    for v in hubs..n {
        let h = rng.below(hubs) as u32;
        edges.push((h, v as u32));
    }
    for _ in 0..n / 4 {
        let a = rng.below(n) as u32;
        let h = rng.below(hubs) as u32;
        if a != h {
            edges.push((a, h));
        }
    }
    undirected(n, edges)
}

/// Discussion-thread shaped graph (chains; REDDIT-B class 1 analogue).
pub fn discussion_thread(rng: &mut Rng, n: usize) -> Csr {
    let n = n.max(4);
    let mut edges = Vec::new();
    for v in 1..n {
        let back = 1 + rng.below(4.min(v));
        edges.push(((v - back) as u32, v as u32));
    }
    for _ in 0..n / 6 {
        let a = rng.below(n) as u32;
        let b = rng.below(n) as u32;
        if a != b {
            edges.push((a, b));
        }
    }
    undirected(n, edges)
}

/// k-NN graph over random 2D points (superpixel analogue). Returns the CSR
/// and the positions (flattened x,y pairs).
pub fn knn_superpixel(rng: &mut Rng, n: usize, k: usize) -> (Csr, Vec<f32>) {
    let n = n.max(k + 1);
    let pos: Vec<(f64, f64)> = (0..n).map(|_| (rng.f64(), rng.f64())).collect();
    let mut edges = Vec::with_capacity(n * k);
    for i in 0..n {
        let mut dists: Vec<(f64, usize)> = (0..n)
            .filter(|&j| j != i)
            .map(|j| {
                let dx = pos[i].0 - pos[j].0;
                let dy = pos[i].1 - pos[j].1;
                (dx * dx + dy * dy, j)
            })
            .collect();
        dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for &(_, j) in dists.iter().take(k) {
            edges.push((i as u32, j as u32));
        }
    }
    let flat: Vec<f32> = pos.iter().flat_map(|&(x, y)| [x as f32, y as f32]).collect();
    (undirected(n, edges), flat)
}

/// Molecule-like graph: random tree + up to `rings` ring closures.
pub fn molecule(rng: &mut Rng, n: usize, rings: usize) -> Csr {
    let n = n.max(2);
    let mut edges = Vec::with_capacity(n + rings);
    for v in 1..n {
        let p = rng.below(v) as u32;
        edges.push((p, v as u32));
    }
    for _ in 0..rings {
        let a = rng.below(n) as u32;
        let b = rng.below(n) as u32;
        if a != b {
            edges.push((a, b));
        }
    }
    undirected(n, edges)
}

fn undirected(n: usize, edges: Vec<(u32, u32)>) -> Csr {
    let mut both = Vec::with_capacity(edges.len() * 2);
    for &(s, d) in &edges {
        both.push((s, d));
        both.push((d, s));
    }
    Csr::from_edges(n, &both).expect("valid edges")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{property, Gen};

    #[test]
    fn ba_is_power_lawish() {
        let mut rng = Rng::new(0);
        let g = preferential_attachment(&mut rng, 2000, 2);
        let deg = g.in_degrees();
        let max = *deg.iter().max().unwrap() as f64;
        let med = {
            let mut d: Vec<u32> = deg.clone();
            d.sort_unstable();
            d[d.len() / 2] as f64
        };
        assert!(max > 8.0 * med, "hub max {max} vs median {med}");
        assert!(g.is_symmetric());
    }

    #[test]
    fn qa_is_hubbier_than_discussion() {
        let mut rng = Rng::new(1);
        let qa = qa_thread(&mut rng, 300);
        let disc = discussion_thread(&mut rng, 300);
        let hubness = |g: &Csr| {
            let deg = g.in_degrees();
            let mean = deg.iter().map(|&d| d as f64).sum::<f64>() / deg.len() as f64;
            *deg.iter().max().unwrap() as f64 / mean.max(1e-9)
        };
        assert!(hubness(&qa) > hubness(&disc));
    }

    #[test]
    fn generators_produce_valid_graphs() {
        property("generators valid", 20, |g: &mut Gen| {
            let n = g.usize_range(5, 120);
            let seed = g.usize_range(0, 1 << 30) as u64;
            let mut rng = Rng::new(seed);
            for csr in [
                preferential_attachment(&mut rng, n, 2),
                qa_thread(&mut rng, n),
                discussion_thread(&mut rng, n),
                molecule(&mut rng, n, 2),
                knn_superpixel(&mut rng, n.max(6), 4).0,
            ] {
                csr.validate().unwrap();
                assert_eq!(csr.num_nodes(), n.max(csr.num_nodes().min(n)));
                assert!(csr.is_symmetric());
            }
        });
    }

    #[test]
    fn knn_degree_at_least_k() {
        let mut rng = Rng::new(3);
        let (g, pos) = knn_superpixel(&mut rng, 60, 4);
        assert_eq!(pos.len(), 120);
        // undirected k-NN: every node has in-degree >= k
        for v in 0..g.num_nodes() {
            assert!(g.in_degree(v) >= 4);
        }
    }
}
