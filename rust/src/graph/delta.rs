//! Incremental graph mutations for dynamic-graph serving.
//!
//! A [`GraphDelta`] appends nodes (with their feature rows) and
//! adds/removes directed edges.  [`GraphDelta::apply_to_csr`] repairs the
//! dst-major CSR **incrementally** — only the rows whose in-neighbour list
//! actually changes are re-merged; clean rows are spliced through verbatim
//! — and the result is *bitwise identical* to rebuilding the CSR from the
//! full post-delta edge set with [`Csr::from_edges`] (set semantics:
//! `(old ∪ added) \ removed`, per-row sorted + deduplicated either way).
//!
//! [`DeltaApplied`] carries the per-node dirty information (which rows
//! changed, which in-degrees changed) that downstream incremental repairs
//! key off: `EdgeForm::apply_delta` recomputes GCN weights only for edges
//! touching a degree-changed endpoint, and [`dirty_frontier`] expands the
//! mutated rows into the L-hop reverse frontier that an L-layer
//! aggregation model must recompute (everything outside the frontier is
//! provably unaffected, which is what lets the serving path patch its
//! logits cache instead of recomputing the whole graph).

use crate::error::{Error, Result};
use crate::util::json::Json;

use super::csr::Csr;

/// A batch of topology/feature mutations against a resident graph.
///
/// New nodes are appended at the end of the id space: if the graph has
/// `n` nodes, the delta's nodes get ids `n .. n + add_nodes`, and
/// `new_features` holds their row-major `[add_nodes, F]` feature rows.
/// Edge endpoints may reference both existing and new ids.  Edge
/// semantics are set-like: the post-delta edge set is
/// `(old ∪ add_edges) \ remove_edges` (adding an existing edge or
/// removing an absent one is a no-op; an edge both added and removed in
/// the same delta ends up removed).
#[derive(Debug, Clone, Default)]
pub struct GraphDelta {
    /// number of nodes appended (ids `n .. n + add_nodes`)
    pub add_nodes: usize,
    /// row-major `[add_nodes, F]` features of the appended nodes
    pub new_features: Vec<f32>,
    /// directed `(src, dst)` edges to add
    pub add_edges: Vec<(u32, u32)>,
    /// directed `(src, dst)` edges to remove
    pub remove_edges: Vec<(u32, u32)>,
}

impl GraphDelta {
    /// Whether the delta mutates anything at all.
    pub fn is_empty(&self) -> bool {
        self.add_nodes == 0 && self.add_edges.is_empty() && self.remove_edges.is_empty()
    }

    /// Full validation against a resident graph of `prev_nodes` nodes with
    /// `feat_dim` features per node: feature-row shape, finite features,
    /// and edge endpoints within the post-delta id space.
    pub fn validate(&self, prev_nodes: usize, feat_dim: usize) -> Result<()> {
        if self.new_features.len() != self.add_nodes * feat_dim {
            return Err(Error::coordinator(format!(
                "delta adds {} nodes but carries {} feature values ({} expected at {} per node)",
                self.add_nodes,
                self.new_features.len(),
                self.add_nodes * feat_dim,
                feat_dim
            )));
        }
        if let Some(i) = self.new_features.iter().position(|v| !v.is_finite()) {
            return Err(Error::coordinator(format!(
                "delta feature value {} at offset {i} is not finite",
                self.new_features[i]
            )));
        }
        self.check_edge_range(prev_nodes)
    }

    /// Shared endpoint bounds check (used by [`Self::validate`] and, for
    /// callers that apply topology without features, [`Self::apply_to_csr`]).
    fn check_edge_range(&self, prev_nodes: usize) -> Result<()> {
        let n_new = prev_nodes + self.add_nodes;
        for &(s, d) in self.add_edges.iter().chain(&self.remove_edges) {
            if s as usize >= n_new || d as usize >= n_new {
                return Err(Error::coordinator(format!(
                    "delta edge ({s},{d}) out of range for {n_new} post-delta nodes"
                )));
            }
        }
        Ok(())
    }

    /// Apply the topology part to a CSR incrementally.  Only rows with
    /// pending adds/removes are re-merged (a sorted three-way merge per
    /// dirty destination); every other row's index block is copied
    /// through.  The output equals `Csr::from_edges` over the post-delta
    /// edge set bitwise (property-tested in this module and in
    /// `rust/tests/delta_parity.rs`).
    pub fn apply_to_csr(&self, csr: &Csr) -> Result<DeltaApplied> {
        let n_old = csr.num_nodes();
        let n_new = n_old + self.add_nodes;
        self.check_edge_range(n_old)?;
        // (dst, src)-sorted, deduplicated mutation lists, consumed by two
        // cursors as the destination walk advances
        let mut adds: Vec<(u32, u32)> = self.add_edges.iter().map(|&(s, d)| (d, s)).collect();
        adds.sort_unstable();
        adds.dedup();
        let mut rems: Vec<(u32, u32)> = self.remove_edges.iter().map(|&(s, d)| (d, s)).collect();
        rems.sort_unstable();
        rems.dedup();

        let mut indptr = vec![0u32; n_new + 1];
        let mut indices: Vec<u32> =
            Vec::with_capacity(csr.num_edges() + adds.len());
        let mut row_changed = vec![false; n_new];
        let mut deg_changed = vec![false; n_new];
        let (mut ai, mut ri) = (0usize, 0usize);
        for v in 0..n_new {
            let old_row: &[u32] = if v < n_old { csr.in_neighbors(v) } else { &[] };
            let a0 = ai;
            while ai < adds.len() && adds[ai].0 == v as u32 {
                ai += 1;
            }
            let r0 = ri;
            while ri < rems.len() && rems[ri].0 == v as u32 {
                ri += 1;
            }
            let row_adds = &adds[a0..ai];
            let row_rems = &rems[r0..ri];
            let start = indices.len();
            if row_adds.is_empty() && row_rems.is_empty() {
                indices.extend_from_slice(old_row);
            } else {
                merge_row(old_row, row_adds, row_rems, &mut indices);
            }
            let new_row = &indices[start..];
            let changed = new_row != old_row;
            // appended nodes count as changed even when isolated: their
            // row, degree, and feature row are all new state
            row_changed[v] = changed || v >= n_old;
            deg_changed[v] = new_row.len() != old_row.len() || v >= n_old;
            indptr[v + 1] = indices.len() as u32;
        }
        Ok(DeltaApplied {
            csr: Csr { indptr, indices },
            prev_nodes: n_old,
            row_changed,
            deg_changed,
        })
    }

    /// Canonical JSON encoding of a delta.  This is the *one* codec for
    /// deltas at rest and on the wire: the network protocol's `update`
    /// payload and the persistence WAL both delegate here, so a record
    /// written by either is readable by both.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("add_nodes", Json::Num(self.add_nodes as f64)),
            ("new_features", json_f32s(&self.new_features)),
            ("add_edges", json_edges(&self.add_edges)),
            ("remove_edges", json_edges(&self.remove_edges)),
        ])
    }

    /// Decode the canonical JSON encoding (see [`Self::to_json`]).
    pub fn from_json(j: &Json) -> Result<GraphDelta> {
        Ok(GraphDelta {
            add_nodes: j.req_usize("add_nodes")?,
            new_features: json_f32s_from(j.req("new_features")?, "new_features")?,
            add_edges: json_edges_from(j.req("add_edges")?, "add_edges")?,
            remove_edges: json_edges_from(j.req("remove_edges")?, "remove_edges")?,
        })
    }
}

// JSON building blocks shared with the wire protocol (`coordinator::net`
// encodes graphs and feature rows with the same conventions).

pub(crate) fn json_f32s(values: &[f32]) -> Json {
    Json::Arr(values.iter().map(|v| Json::Num(*v as f64)).collect())
}

/// Non-finite floats serialize as JSON `null`; decode them back to NaN so
/// a roundtrip is total.
pub(crate) fn json_f32s_from(j: &Json, field: &str) -> Result<Vec<f32>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| Error::json(format!("field '{field}' is not an array")))?;
    arr.iter()
        .map(|v| match v {
            Json::Num(n) => Ok(*n as f32),
            Json::Null => Ok(f32::NAN),
            _ => Err(Error::json(format!("field '{field}' has a non-number"))),
        })
        .collect()
}

pub(crate) fn json_edges(edges: &[(u32, u32)]) -> Json {
    Json::Arr(
        edges
            .iter()
            .map(|(s, d)| Json::Arr(vec![Json::Num(*s as f64), Json::Num(*d as f64)]))
            .collect(),
    )
}

pub(crate) fn json_edges_from(j: &Json, field: &str) -> Result<Vec<(u32, u32)>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| Error::json(format!("field '{field}' is not an array")))?;
    arr.iter()
        .map(|pair| {
            let s = pair
                .idx(0)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::json(format!("field '{field}': bad edge pair")))?;
            let d = pair
                .idx(1)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::json(format!("field '{field}': bad edge pair")))?;
            if s < 0.0 || d < 0.0 || s > u32::MAX as f64 || d > u32::MAX as f64 {
                return Err(Error::json(format!(
                    "field '{field}': edge endpoint out of u32 range"
                )));
            }
            Ok((s as u32, d as u32))
        })
        .collect()
}

/// Sorted merge of one destination row: `(old ∪ adds) \ rems`, ascending,
/// deduplicated.  All three inputs are sorted ascending (adds/rems by the
/// src component).
fn merge_row(old: &[u32], adds: &[(u32, u32)], rems: &[(u32, u32)], out: &mut Vec<u32>) {
    let base = out.len();
    let (mut oi, mut ai) = (0usize, 0usize);
    let mut ri = 0usize;
    while oi < old.len() || ai < adds.len() {
        let take_old = match (old.get(oi), adds.get(ai)) {
            (Some(&o), Some(&(_, a))) => o <= a,
            (Some(_), None) => true,
            _ => false,
        };
        let s = if take_old {
            let s = old[oi];
            oi += 1;
            s
        } else {
            let s = adds[ai].1;
            ai += 1;
            s
        };
        while ri < rems.len() && rems[ri].1 < s {
            ri += 1;
        }
        if ri < rems.len() && rems[ri].1 == s {
            continue; // removed
        }
        // dedup within THIS row only (out is the shared indices vec)
        if out.len() == base || out[out.len() - 1] != s {
            out.push(s);
        }
    }
}

/// Result of applying a [`GraphDelta`] to a CSR: the repaired structure
/// plus the dirty-row bookkeeping downstream incremental repairs need.
#[derive(Debug, Clone)]
pub struct DeltaApplied {
    /// post-delta CSR (bitwise equal to a from-scratch rebuild)
    pub csr: Csr,
    /// node count before the delta
    pub prev_nodes: usize,
    /// per post-delta node: in-neighbour list changed (appended nodes
    /// always true)
    pub row_changed: Vec<bool>,
    /// per post-delta node: in-degree changed (⊆ `row_changed`; appended
    /// nodes always true).  A degree change moves the node's d̃ and hence
    /// the GCN weight of *every* edge incident to it.
    pub deg_changed: Vec<bool>,
}

impl DeltaApplied {
    pub fn num_changed_rows(&self) -> usize {
        self.row_changed.iter().filter(|&&c| c).count()
    }
}

/// Per-layer dirty row sets for an `layers`-deep aggregation model over
/// the **post-delta** CSR.
///
/// Layer 1's output row changes for: mutated destinations (`row_changed`),
/// and destinations with a degree-changed in-neighbour (their GCN edge
/// weight moved).  Each further layer expands one reverse hop: a row is
/// dirty at layer `l+1` if it was dirty at `l` (self term) or any of its
/// in-neighbours was (aggregation term).  Everything outside `out[l]` is
/// unaffected at that depth, so a serving cache may keep those rows —
/// the sets are deliberately *sound supersets*: re-computing a member row
/// whose inputs happen to be unchanged reproduces its value bitwise.
pub fn dirty_frontier(csr: &Csr, applied: &DeltaApplied, layers: usize) -> Vec<Vec<u32>> {
    let n = csr.num_nodes();
    let mut mask = applied.row_changed.clone();
    debug_assert_eq!(mask.len(), n);
    for v in 0..n {
        if mask[v] {
            continue;
        }
        if csr
            .in_neighbors(v)
            .iter()
            .any(|&u| applied.deg_changed[u as usize])
        {
            mask[v] = true;
        }
    }
    let collect = |m: &[bool]| -> Vec<u32> {
        m.iter()
            .enumerate()
            .filter_map(|(v, &d)| d.then_some(v as u32))
            .collect()
    };
    let mut out = Vec::with_capacity(layers);
    if layers == 0 {
        return out;
    }
    out.push(collect(&mask));
    for _ in 1..layers {
        let prev = mask.clone();
        for v in 0..n {
            if mask[v] {
                continue;
            }
            if csr.in_neighbors(v).iter().any(|&u| prev[u as usize]) {
                mask[v] = true;
            }
        }
        out.push(collect(&mask));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{property, Gen};
    use crate::util::rng::Rng;
    use std::collections::BTreeSet;

    fn path4() -> Csr {
        // 0 <-> 1 <-> 2 <-> 3
        Csr::from_edges(4, &[(0, 1), (1, 0), (1, 2), (2, 1), (2, 3), (3, 2)]).unwrap()
    }

    #[test]
    fn add_edge_repairs_one_row() {
        let csr = path4();
        let delta = GraphDelta {
            add_edges: vec![(3, 0)],
            ..Default::default()
        };
        let applied = delta.apply_to_csr(&csr).unwrap();
        assert_eq!(applied.csr.in_neighbors(0), &[1, 3]);
        assert!(applied.row_changed[0] && applied.deg_changed[0]);
        assert!(!applied.row_changed[1] && !applied.deg_changed[3]);
        let rebuilt = Csr::from_edges(
            4,
            &[(0, 1), (1, 0), (1, 2), (2, 1), (2, 3), (3, 2), (3, 0)],
        )
        .unwrap();
        assert_eq!(applied.csr, rebuilt);
    }

    #[test]
    fn add_existing_edge_is_a_clean_noop() {
        let csr = path4();
        let delta = GraphDelta {
            add_edges: vec![(0, 1)],
            ..Default::default()
        };
        let applied = delta.apply_to_csr(&csr).unwrap();
        assert_eq!(applied.csr, csr);
        assert_eq!(applied.num_changed_rows(), 0);
    }

    #[test]
    fn remove_and_simultaneous_add_remove() {
        let csr = path4();
        let applied = GraphDelta {
            remove_edges: vec![(1, 2), (9, 9)],
            ..Default::default()
        };
        assert!(applied.apply_to_csr(&csr).is_err()); // out of range
        let applied = GraphDelta {
            // (3,0) both added and removed → ends removed
            add_edges: vec![(3, 0)],
            remove_edges: vec![(3, 0), (1, 2)],
            ..Default::default()
        }
        .apply_to_csr(&csr)
        .unwrap();
        assert_eq!(applied.csr.in_neighbors(0), &[1]);
        assert_eq!(applied.csr.in_neighbors(2), &[3]);
        assert!(applied.row_changed[2] && applied.deg_changed[2]);
        assert!(!applied.row_changed[0]);
    }

    #[test]
    fn appended_nodes_are_always_dirty() {
        let csr = path4();
        let applied = GraphDelta {
            add_nodes: 2,
            new_features: vec![],
            add_edges: vec![(4, 0), (0, 5)],
            ..Default::default()
        }
        .apply_to_csr(&csr)
        .unwrap();
        assert_eq!(applied.csr.num_nodes(), 6);
        assert_eq!(applied.csr.in_neighbors(5), &[0]);
        assert!(applied.row_changed[4] && applied.deg_changed[4]); // isolated but new
        assert!(applied.row_changed[5]);
        assert!(applied.row_changed[0]); // gained in-edge from 4
    }

    #[test]
    fn validate_checks_features() {
        let d = GraphDelta {
            add_nodes: 2,
            new_features: vec![0.0; 3],
            ..Default::default()
        };
        assert!(d.validate(4, 2).is_err()); // wrong length
        let d = GraphDelta {
            add_nodes: 1,
            new_features: vec![0.0, f32::NAN],
            ..Default::default()
        };
        assert!(d.validate(4, 2).is_err()); // non-finite
        let d = GraphDelta {
            add_nodes: 1,
            new_features: vec![0.0, 1.0],
            add_edges: vec![(4, 0)],
            ..Default::default()
        };
        d.validate(4, 2).unwrap();
    }

    #[test]
    fn dirty_frontier_expands_by_reverse_hops() {
        // path 0-1-2-3, edge added at (3,0): layer-1 dirty = {0} ∪
        // out-neighbours of deg-changed {0} = {0, 1}; layer 2 adds 2.
        let csr = path4();
        let applied = GraphDelta {
            add_edges: vec![(3, 0)],
            ..Default::default()
        }
        .apply_to_csr(&csr)
        .unwrap();
        let dirty = dirty_frontier(&applied.csr, &applied, 3);
        assert_eq!(dirty[0], vec![0, 1]);
        assert_eq!(dirty[1], vec![0, 1, 2]);
        assert_eq!(dirty[2], vec![0, 1, 2, 3]);
    }

    #[test]
    fn json_codec_roundtrips_exactly() {
        let d = GraphDelta {
            add_nodes: 2,
            new_features: vec![0.25, -1.5, 3.0e-8, 42.0],
            add_edges: vec![(0, 5), (4, 4)],
            remove_edges: vec![(1, 0)],
        };
        let text = d.to_json().to_string();
        let back = GraphDelta::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.add_nodes, d.add_nodes);
        // f32 → f64 → f32 through JSON is exact for every f32
        assert_eq!(
            back.new_features.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            d.new_features.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(back.add_edges, d.add_edges);
        assert_eq!(back.remove_edges, d.remove_edges);

        // malformed shapes are descriptive errors, not panics
        let bad = crate::util::json::parse(r#"{"add_nodes": 1}"#).unwrap();
        assert!(GraphDelta::from_json(&bad).is_err());
    }

    #[test]
    fn incremental_apply_matches_full_rebuild_property() {
        property("delta csr == from_edges rebuild", 60, |g: &mut Gen| {
            let n0 = g.usize_range(2, 50);
            let seed = g.usize_range(0, 1 << 30) as u64;
            let mut rng = Rng::new(seed);
            let csr = crate::graph::generate::preferential_attachment(&mut rng, n0, 2);
            let mut edge_set: BTreeSet<(u32, u32)> = csr.edge_list().into_iter().collect();

            let add_nodes = g.usize_range(0, 4);
            let n1 = n0 + add_nodes;
            let adds: Vec<(u32, u32)> = (0..g.usize_range(0, 12))
                .map(|_| (g.usize_range(0, n1) as u32, g.usize_range(0, n1) as u32))
                .collect();
            // removals: mix of existing and absent edges
            let existing: Vec<(u32, u32)> = edge_set.iter().copied().collect();
            let mut rems: Vec<(u32, u32)> = (0..g.usize_range(0, 6))
                .map(|_| existing[g.usize_range(0, existing.len())])
                .collect();
            rems.push((
                g.usize_range(0, n1) as u32,
                g.usize_range(0, n1) as u32,
            ));

            let delta = GraphDelta {
                add_nodes,
                new_features: vec![],
                add_edges: adds.clone(),
                remove_edges: rems.clone(),
            };
            let applied = delta.apply_to_csr(&csr).unwrap();
            applied.csr.validate().unwrap();

            for e in adds {
                edge_set.insert(e);
            }
            for e in rems {
                edge_set.remove(&e);
            }
            let full: Vec<(u32, u32)> = edge_set.into_iter().collect();
            let rebuilt = Csr::from_edges(n1, &full).unwrap();
            assert_eq!(applied.csr, rebuilt, "seed {seed}");

            // dirty bookkeeping is consistent with the structural diff
            for v in 0..n1 {
                let old_row: &[u32] = if v < n0 { csr.in_neighbors(v) } else { &[] };
                let changed = rebuilt.in_neighbors(v) != old_row || v >= n0;
                assert_eq!(applied.row_changed[v], changed, "row {v}");
                let degc = rebuilt.in_degree(v) != old_row.len() || v >= n0;
                assert_eq!(applied.deg_changed[v], degc, "deg {v}");
            }
        });
    }
}
