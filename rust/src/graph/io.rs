//! Binary dataset IO — reads the files written by `python/compile/datasets.py`.
//!
//! Format (little endian; see the python module docstring for the spec):
//! magic "A2QD", version u32, kind u32 (0 node-level, 1 graph-level), then
//! the kind-specific payload.

use std::io::Read;
use std::path::Path;

use crate::error::{Error, Result};

use super::csr::Csr;

/// A node-level dataset: one graph, features, labels, semi-supervised masks.
#[derive(Debug, Clone)]
pub struct NodeData {
    pub name: String,
    pub csr: Csr,
    pub num_features: usize,
    pub num_classes: usize,
    /// row-major [N, F]
    pub features: Vec<f32>,
    pub labels: Vec<i32>,
    pub train_mask: Vec<bool>,
    pub val_mask: Vec<bool>,
    pub test_mask: Vec<bool>,
}

impl NodeData {
    pub fn num_nodes(&self) -> usize {
        self.csr.num_nodes()
    }
    pub fn feature_row(&self, v: usize) -> &[f32] {
        &self.features[v * self.num_features..(v + 1) * self.num_features]
    }
}

/// One small graph of a graph-level dataset.
#[derive(Debug, Clone)]
pub struct SmallGraph {
    pub csr: Csr,
    /// row-major [n, F]
    pub features: Vec<f32>,
    /// class label, or f32-bits for regression targets
    pub target_class: i32,
    pub target_value: f32,
}

impl SmallGraph {
    pub fn num_nodes(&self) -> usize {
        self.csr.num_nodes()
    }
}

/// A graph-level dataset (classification if `num_classes > 0`, else
/// regression).
#[derive(Debug, Clone)]
pub struct GraphSet {
    pub name: String,
    pub num_features: usize,
    pub num_classes: usize,
    pub graphs: Vec<SmallGraph>,
}

/// Either kind of dataset.
#[derive(Debug, Clone)]
pub enum Dataset {
    Node(NodeData),
    Graphs(GraphSet),
}

impl Dataset {
    pub fn name(&self) -> &str {
        match self {
            Dataset::Node(d) => &d.name,
            Dataset::Graphs(d) => &d.name,
        }
    }
}

struct Reader {
    buf: Vec<u8>,
    pos: usize,
}

impl Reader {
    fn u32(&mut self) -> Result<u32> {
        if self.pos + 4 > self.buf.len() {
            return Err(Error::dataset("truncated file (u32)"));
        }
        let v = u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        Ok(v)
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(self.u32()? as i32)
    }

    fn u32_vec(&mut self, n: usize) -> Result<Vec<u32>> {
        if self.pos + 4 * n > self.buf.len() {
            return Err(Error::dataset("truncated file (u32 vec)"));
        }
        let out = self.buf[self.pos..self.pos + 4 * n]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.pos += 4 * n;
        Ok(out)
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        Ok(self.u32_vec(n)?.into_iter().map(f32::from_bits).collect())
    }

    fn i32_vec(&mut self, n: usize) -> Result<Vec<i32>> {
        Ok(self.u32_vec(n)?.into_iter().map(|v| v as i32).collect())
    }

    fn mask(&mut self, n: usize) -> Result<Vec<bool>> {
        if self.pos + n > self.buf.len() {
            return Err(Error::dataset("truncated file (mask)"));
        }
        let out = self.buf[self.pos..self.pos + n].iter().map(|&b| b != 0).collect();
        self.pos += n;
        Ok(out)
    }
}

/// Load a dataset binary written by the python generator.
pub fn load_dataset(path: &Path) -> Result<Dataset> {
    let mut buf = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut buf)?;
    if buf.len() < 12 || &buf[..4] != b"A2QD" {
        return Err(Error::dataset(format!(
            "{}: not an A2QD file",
            path.display()
        )));
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_default();
    let mut r = Reader { buf, pos: 4 };
    let version = r.u32()?;
    if version != 1 {
        return Err(Error::dataset(format!("unsupported version {version}")));
    }
    let kind = r.u32()?;
    match kind {
        0 => load_node(&mut r, name).map(Dataset::Node),
        1 => load_graphs(&mut r, name).map(Dataset::Graphs),
        k => Err(Error::dataset(format!("unknown kind {k}"))),
    }
}

fn load_node(r: &mut Reader, name: String) -> Result<NodeData> {
    let n = r.u32()? as usize;
    let f = r.u32()? as usize;
    let c = r.u32()? as usize;
    let nnz = r.u32()? as usize;
    let indptr = r.u32_vec(n + 1)?;
    let indices = r.u32_vec(nnz)?;
    let features = r.f32_vec(n * f)?;
    let labels = r.i32_vec(n)?;
    let train_mask = r.mask(n)?;
    let val_mask = r.mask(n)?;
    let test_mask = r.mask(n)?;
    let csr = Csr { indptr, indices };
    csr.validate()?;
    Ok(NodeData {
        name,
        csr,
        num_features: f,
        num_classes: c,
        features,
        labels,
        train_mask,
        val_mask,
        test_mask,
    })
}

fn load_graphs(r: &mut Reader, name: String) -> Result<GraphSet> {
    let g = r.u32()? as usize;
    let f = r.u32()? as usize;
    let c = r.u32()? as usize;
    let mut graphs = Vec::with_capacity(g);
    for _ in 0..g {
        let n = r.u32()? as usize;
        let nnz = r.u32()? as usize;
        let indptr = r.u32_vec(n + 1)?;
        let indices = r.u32_vec(nnz)?;
        let features = r.f32_vec(n * f)?;
        let (target_class, target_value) = if c == 0 {
            let v = r.f32()?;
            (0, v)
        } else {
            let l = r.i32()?;
            (l, l as f32)
        };
        let csr = Csr { indptr, indices };
        csr.validate()?;
        graphs.push(SmallGraph {
            csr,
            features,
            target_class,
            target_value,
        });
    }
    Ok(GraphSet {
        name,
        num_features: f,
        num_classes: c,
        graphs,
    })
}

/// Convenience: load `artifacts/data/<name>.bin`.
pub fn load_named(artifacts: &Path, name: &str) -> Result<Dataset> {
    load_dataset(&artifacts.join("data").join(format!("{name}.bin")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Hand-craft a tiny node-level file matching the python format.
    fn write_tiny_node(path: &Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"A2QD").unwrap();
        for v in [1u32, 0u32] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        // N=2, F=2, C=2, nnz=2
        for v in [2u32, 2, 2, 2] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        for v in [0u32, 1, 2] {
            f.write_all(&v.to_le_bytes()).unwrap(); // indptr
        }
        for v in [1u32, 0] {
            f.write_all(&v.to_le_bytes()).unwrap(); // indices
        }
        for v in [1.0f32, 0.0, 0.0, 1.0] {
            f.write_all(&v.to_le_bytes()).unwrap(); // features
        }
        for v in [0i32, 1] {
            f.write_all(&v.to_le_bytes()).unwrap(); // labels
        }
        f.write_all(&[1, 0]).unwrap(); // train
        f.write_all(&[0, 1]).unwrap(); // val
        f.write_all(&[0, 0]).unwrap(); // test
    }

    #[test]
    fn reads_tiny_node_file() {
        let dir = std::env::temp_dir().join("a2q_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.bin");
        write_tiny_node(&path);
        let ds = load_dataset(&path).unwrap();
        match ds {
            Dataset::Node(d) => {
                assert_eq!(d.num_nodes(), 2);
                assert_eq!(d.num_features, 2);
                assert_eq!(d.csr.in_neighbors(0), &[1]);
                assert_eq!(d.feature_row(1), &[0.0, 1.0]);
                assert_eq!(d.labels, vec![0, 1]);
                assert_eq!(d.train_mask, vec![true, false]);
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("a2q_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOPE0000").unwrap();
        assert!(load_dataset(&path).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("a2q_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.bin");
        write_tiny_node(&path);
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 4]).unwrap();
        assert!(load_dataset(&path).is_err());
    }
}
