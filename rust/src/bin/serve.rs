//! `a2q-serve` — stand up the TCP serving front end.
//!
//! Serves a mock-backed model by default (protocol/ops testing without
//! artifacts); pass `--artifact <name>` to serve a real AOT artifact via
//! the PJRT runtime.  Network knobs come from `A2Q_*` environment
//! variables (see the README's "Network serving" section); the CLI options
//! below override them when set.
//!
//!   a2q-serve run --listen 127.0.0.1:7462 --duration-s 30
//!   a2q-serve run --artifact gcn-synth-cora-a2q --target-p99-us 5000

use std::sync::Arc;
use std::time::Duration;

use a2q::coordinator::net::NetConfig;
use a2q::coordinator::{
    synthetic_node_session, AdaptiveWait, BatcherConfig, Coordinator, MockExecutor,
    NativeExecutor, NetServer, PjrtExecutor, SuperviseConfig,
};
use a2q::error::Result;
use a2q::runtime::{ArtifactIndex, EngineHandle, PersistConfig};
use a2q::util::cli::{App, CommandSpec};
use a2q::util::json::Json;

fn app() -> App {
    App::new("a2q-serve", "TCP serving front end for the A2Q coordinator").command(
        CommandSpec::new("run", "bind and serve")
            .opt("listen", "", "listen address (overrides A2Q_LISTEN)")
            .opt("model", "mock", "served model name")
            .opt("artifact", "", "serve this AOT artifact instead of the mock")
            .opt("mock-latency-us", "200", "mock executor latency (us)")
            .opt("out-dim", "8", "mock executor output dimension")
            .opt(
                "synthetic",
                "0",
                "serve a deterministic native session over a synthetic graph \
                 of this many nodes (durable-state / crash-recovery testing)",
            )
            .opt("synthetic-seed", "42", "seed of the synthetic session")
            .opt(
                "state-dir",
                "",
                "durable state directory for the synthetic session \
                 (overrides A2Q_STATE_DIR; restore runs before listening)",
            )
            .opt("max-wait-us", "500", "batcher flush deadline (us)")
            .opt("queue-cap", "256", "admission queue depth per model")
            .opt("rate-rps", "-1", "per-client rate limit (overrides A2Q_RATE_RPS)")
            .opt(
                "target-p99-us",
                "-1",
                "adaptive batching latency target (overrides A2Q_TARGET_P99_US)",
            )
            .opt("duration-s", "0", "serve this long then drain (0 = forever)"),
    )
}

fn main() {
    // single-command binary: let `a2q-serve --listen ...` work without the
    // explicit `run` in front
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(|a| a.starts_with("--")).unwrap_or(true)
        && args.first().map(|a| a != "--help" && a != "-h").unwrap_or(false)
    {
        args.insert(0, "run".to_string());
    }
    let matches = match app().parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(matches) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(m: a2q::util::cli::Matches) -> Result<()> {
    let mut cfg = NetConfig::from_env()?;
    let listen = m.req("listen")?;
    if !listen.is_empty() {
        cfg.listen = listen.to_string();
    }
    let rate = m.get_f64("rate-rps")?;
    if rate >= 0.0 {
        cfg.rate_rps = rate;
    }
    let target = m.get_f64("target-p99-us")?;
    if target >= 0.0 {
        cfg.target_p99_us = target as u64;
    }

    let max_wait = Duration::from_micros(m.get_usize("max-wait-us")? as u64);
    let mut batcher = BatcherConfig {
        max_wait,
        queue_cap: m.get_usize("queue-cap")?,
        ..BatcherConfig::default()
    };
    if cfg.target_p99_us > 0 {
        // the net tuner drives the flush deadline between max_wait/8 and
        // 4x max_wait, chasing the configured p99 target
        batcher.adaptive_wait = Some(AdaptiveWait::new(
            max_wait,
            max_wait / 8,
            max_wait * 4,
        ));
    }

    let mut coord = Coordinator::new();
    // supervision knobs (restart budget, breaker) apply to every model
    // registered below
    coord.set_supervision(SuperviseConfig::from_env()?);
    let artifact_name = m.req("artifact")?;
    let synthetic = m.get_usize("synthetic")?;
    let model_name = if synthetic > 0 {
        // deterministic native session: same (n, seed) ⇒ bitwise-identical
        // logits across processes, which is what the crash-recovery CI leg
        // asserts across a kill -9 and restart
        let seed = m.get_usize("synthetic-seed")? as u64;
        let (model, ds) = synthetic_node_session(synthetic, seed)?;
        let name = model.name.clone();
        let mut exec = NativeExecutor::new(model, Some(&ds))?;
        let state_dir = m.req("state-dir")?;
        if let Some(pcfg) = PersistConfig::from_env_with_dir(Some(state_dir))? {
            // restore-then-listen: recovery replay finishes (or fails
            // loudly) before the first connection is accepted
            let dir = pcfg.dir.display().to_string();
            let (restored, report) = exec.with_persistence(pcfg)?;
            exec = restored;
            println!(
                "a2q-serve: durable state at {dir}: snapshot restored={} \
                 (epoch {}), replayed {} wal record(s), dropped {} torn byte(s){}",
                report.restored_snapshot,
                report.snapshot_epoch,
                report.replayed_deltas,
                report.dropped_bytes,
                report
                    .dropped_note
                    .as_deref()
                    .map(|n| format!(" [{n}]"))
                    .unwrap_or_default(),
            );
        }
        coord.add_model(&name, Arc::new(exec), batcher);
        name
    } else if artifact_name.is_empty() {
        let name = m.req("model")?.to_string();
        coord.add_model(
            &name,
            Arc::new(MockExecutor {
                out_dim: m.get_usize("out-dim")?,
                latency: Duration::from_micros(m.get_usize("mock-latency-us")? as u64),
            }),
            batcher,
        );
        name
    } else {
        let artifacts = a2q::artifacts_dir();
        let index = ArtifactIndex::load(&artifacts)?;
        let artifact = index.artifact(artifact_name)?;
        let dataset = a2q::graph::io::load_named(&artifacts, &artifact.dataset)?;
        let engine = EngineHandle::spawn()?;
        let exec = Arc::new(PjrtExecutor::new(engine, &artifact, Some(&dataset))?);
        coord.add_model(&artifact.name, exec, batcher);
        artifact.name.clone()
    };

    let server = NetServer::start(coord, cfg)?;
    println!("a2q-serve: model '{model_name}' listening on {}", server.local_addr());

    let duration_s = m.get_usize("duration-s")?;
    if duration_s == 0 {
        // no signal handling without external crates: serve until killed
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(duration_s as u64));
    let metrics = server.metrics_json();
    let report = server.drain();
    let summary = Json::obj(vec![
        ("metrics", metrics),
        (
            "drain",
            Json::obj(vec![
                (
                    "unreplied_in_flight",
                    Json::Num(report.unreplied_in_flight as f64),
                ),
                ("open_conns", Json::Num(report.open_conns as f64)),
                ("took_ms", Json::Num(report.took.as_secs_f64() * 1e3)),
            ]),
        ),
    ]);
    println!("{}", summary.to_string_pretty());
    Ok(())
}
