//! `a2q-loadgen` — closed-loop load generator for `a2q-serve`.
//!
//! Drives N parallel connections, each sending classify requests
//! back-to-back, and prints a JSON tally in which every request is
//! accounted for: `sent == ok + rejected + errors + io_errors`.  A
//! well-behaved server keeps `io_errors` at zero even at 10x overload —
//! refusals must arrive as on-protocol `rejected` frames, not dropped
//! connections.
//!
//!   a2q-loadgen run --addr 127.0.0.1:7462 --conns 40 --requests 250

use std::time::Duration;

use a2q::coordinator::net::{run_load, LoadConfig, RetryPolicy};
use a2q::error::Result;
use a2q::util::cli::{App, CommandSpec};

fn app() -> App {
    App::new("a2q-loadgen", "closed-loop load generator for the A2Q wire protocol").command(
        CommandSpec::new("run", "run one load scenario")
            .opt_req("addr", "server address (host:port)")
            .opt("conns", "8", "parallel connections")
            .opt("requests", "100", "requests per connection")
            .opt("model", "mock", "model name to query")
            .opt("nodes-per-req", "2", "node ids per classify request")
            .opt("node-space", "64", "node ids are drawn modulo this")
            .opt("pace-us", "0", "sleep between requests (0 = closed loop)")
            .opt(
                "retries",
                "0",
                "extra attempts per request on rejection/transport error (0 = never retry)",
            )
            .opt(
                "deadline-ms",
                "0",
                "wall-clock budget per request across all attempts (0 = unbounded)",
            ),
    )
}

fn main() {
    // single-command binary: allow `a2q-loadgen --addr ...` without `run`
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(|a| a.starts_with("--")).unwrap_or(true)
        && args.first().map(|a| a != "--help" && a != "-h").unwrap_or(false)
    {
        args.insert(0, "run".to_string());
    }
    let matches = match app().parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(matches) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(m: a2q::util::cli::Matches) -> Result<()> {
    let deadline_ms = m.get_usize("deadline-ms")? as u64;
    let retry = RetryPolicy {
        max_retries: m.get_usize("retries")? as u32,
        deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
        ..RetryPolicy::default()
    };
    let cfg = LoadConfig {
        conns: m.get_usize("conns")?,
        requests_per_conn: m.get_usize("requests")?,
        model: m.req("model")?.to_string(),
        nodes_per_req: m.get_usize("nodes-per-req")?,
        node_space: m.get_usize("node-space")?.max(1) as u32,
        pace: Duration::from_micros(m.get_usize("pace-us")? as u64),
        retry,
    };
    let report = run_load(m.req("addr")?, &cfg)?;
    println!("{}", report.to_json().to_string_pretty());
    if report.io_errors > 0 {
        // transport failures are the one outcome class a graceful server
        // must never produce; make them visible to scripts
        std::process::exit(1);
    }
    Ok(())
}
