//! Cycle-accurate simulator of the paper's bit-serial GNN accelerator
//! (§A.7.5) and its energy model (§A.7.6).
//!
//! Architecture being modelled:
//! * 256 Processing Engines × 16 bit-serial MACs (Stripes-style, Judd et
//!   al. 2016): an m-bit node feature × 4-bit weight multiply takes m
//!   cycles; only the node features are serialized.
//! * Update phase `B = X·W`: 256 consecutive X rows × one W column mapped
//!   per phase; PEs run in lockstep, so a 256-row tile costs
//!   `ceil(F_in/16) · max(bits in tile)` cycles per output column.
//! * Aggregation phase `X' = Â·B` on CSR with full-zero-row elimination;
//!   nodes are processed 256 at a time **sorted by in-degree descending**
//!   (the paper's load-balancing), costing `max(deg in group) · ceil(F/16)`
//!   add-cycles per group.
//! * On-chip SRAM: Input 2 MB, Output 2 MB (swapped between layers), Edge
//!   256 KB, Weight 256 KB; spills induce extra off-chip (HBM) traffic.
//! * Energy: 45 nm op energies (paper Fig. 21), CACTI-style SRAM access
//!   cost, HBM at 7 pJ/bit; the GPU comparison point runs the same FLOPs in
//!   fp32 with DRAM-resident data.
//!
//! "Cycle-accurate" here means deterministic per-tile cycle accounting of
//! the lockstep dataflow — the same methodology the paper uses for its
//! speedup tables (their simulator, like ours, does not model pipeline
//! hazards inside the MAC array because the dataflow is statically
//! scheduled).

pub mod compare;
pub mod config;
pub mod energy;
pub mod simulator;

pub use compare::{simulate_model_cycles, speedup_vs_dq, ModelWorkload};
pub use config::AccelConfig;
pub use energy::{EnergyModel, EnergyReport};
pub use simulator::{CycleStats, Simulator};
