//! Energy model (paper §A.7.6, Fig. 21 op-energy table).
//!
//! 45 nm op energies from Han et al. (2016) / Sze et al. (2020) as printed
//! in the paper's Fig. 21; HBM at 7 pJ/bit (O'Connor 2014); SRAM at the
//! 32-bit/32 KB point from the same table (CACTI-calibrated).  The "GPU"
//! comparison point executes the identical op counts in fp32 with
//! DRAM-resident tensors — a model, not a measurement (DESIGN.md §3).

use super::simulator::CycleStats;

/// Energy per operation, picojoules.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    pub int8_add_pj: f64,
    pub int8_mult_pj: f64,
    pub fp32_add_pj: f64,
    pub fp32_mult_pj: f64,
    /// per 32-bit SRAM access (32 KB array)
    pub sram_32b_pj: f64,
    /// per bit of HBM traffic
    pub hbm_per_bit_pj: f64,
    /// per 32-bit DRAM access (GPU side)
    pub dram_32b_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            int8_add_pj: 0.03,
            int8_mult_pj: 0.2,
            fp32_add_pj: 0.9,
            fp32_mult_pj: 3.7,
            sram_32b_pj: 5.0,
            hbm_per_bit_pj: 7.0,
            dram_32b_pj: 640.0,
        }
    }
}

/// Energy breakdown of one simulated inference (nanojoules).
#[derive(Debug, Clone, Default)]
pub struct EnergyReport {
    pub compute_nj: f64,
    pub sram_nj: f64,
    pub offchip_nj: f64,
}

impl EnergyReport {
    pub fn total_nj(&self) -> f64 {
        self.compute_nj + self.sram_nj + self.offchip_nj
    }
}

impl EnergyModel {
    /// Accelerator energy from simulator counters.  Bit-serial multiplies
    /// scale with streamed bits: an m-bit×4-bit mult ≈ (m/8)·E(int8 mult).
    pub fn accelerator(&self, s: &CycleStats) -> EnergyReport {
        let mult_pj = s.int_mult_bit_cycles as f64 / 8.0 * self.int8_mult_pj;
        let add_pj = s.int_adds as f64 * self.int8_add_pj;
        let float_pj = s.float_ops as f64 * self.fp32_mult_pj;
        let sram_pj = s.sram_bytes as f64 / 4.0 * self.sram_32b_pj;
        let hbm_pj = s.hbm_bytes as f64 * 8.0 * self.hbm_per_bit_pj;
        EnergyReport {
            compute_nj: (mult_pj + add_pj + float_pj) / 1e3,
            sram_nj: sram_pj / 1e3,
            offchip_nj: hbm_pj / 1e3,
        }
    }

    /// GPU-like fp32 baseline running the same logical op counts with
    /// DRAM-resident tensors (fp32 features, 32-bit accesses).
    pub fn gpu_fp32(&self, s: &CycleStats) -> EnergyReport {
        let mult_pj = s.int_mults as f64 * self.fp32_mult_pj;
        let add_pj = (s.int_adds + s.int_mults) as f64 * self.fp32_add_pj;
        let float_pj = s.float_ops as f64 * self.fp32_mult_pj;
        // fp32 traffic is 32/avg-bits larger; approximate with 8x the
        // quantized byte volume (4 bits avg → 8×), all DRAM.
        let traffic_words = (s.sram_bytes + s.hbm_bytes) as f64 * 8.0 / 4.0;
        let dram_pj = traffic_words * self.dram_32b_pj;
        EnergyReport {
            compute_nj: (mult_pj + add_pj + float_pj) / 1e3,
            sram_nj: 0.0,
            offchip_nj: dram_pj / 1e3,
        }
    }

    /// Energy-efficiency ratio (GPU / accelerator), the Fig. 22 metric.
    pub fn efficiency_vs_gpu(&self, s: &CycleStats) -> f64 {
        let acc = self.accelerator(s).total_nj();
        let gpu = self.gpu_fp32(s).total_nj();
        if acc <= 0.0 {
            0.0
        } else {
            gpu / acc
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> CycleStats {
        CycleStats {
            update_cycles: 1000,
            aggregate_cycles: 500,
            int_mults: 1_000_000,
            int_mult_bit_cycles: 4_000_000,
            int_adds: 1_200_000,
            float_ops: 10_000,
            sram_bytes: 1 << 20,
            hbm_bytes: 1 << 18,
            ..Default::default()
        }
    }

    #[test]
    fn table_values_match_fig21() {
        let m = EnergyModel::default();
        assert_eq!(m.int8_add_pj, 0.03);
        assert_eq!(m.int8_mult_pj, 0.2);
        assert_eq!(m.fp32_mult_pj, 3.7);
        assert_eq!(m.dram_32b_pj, 640.0);
        // relative cost column: fp32 mult = 123x int8 add (paper: 123)
        assert!((m.fp32_mult_pj / m.int8_add_pj - 123.0).abs() < 1.0);
    }

    #[test]
    fn accelerator_beats_gpu_model() {
        let m = EnergyModel::default();
        let s = stats();
        let eff = m.efficiency_vs_gpu(&s);
        assert!(eff > 5.0, "efficiency {eff}");
    }

    #[test]
    fn memory_dominates_for_low_compute(){
        let m = EnergyModel::default();
        let s = CycleStats {
            int_mults: 10,
            int_mult_bit_cycles: 40,
            int_adds: 10,
            sram_bytes: 1 << 20,
            hbm_bytes: 1 << 20,
            ..Default::default()
        };
        let rep = m.accelerator(&s);
        assert!(rep.sram_nj + rep.offchip_nj > rep.compute_nj * 100.0);
    }

    #[test]
    fn bit_scaling_lowers_mult_energy() {
        let m = EnergyModel::default();
        let low = CycleStats {
            int_mults: 1000,
            int_mult_bit_cycles: 2000, // 2-bit features
            ..Default::default()
        };
        let high = CycleStats {
            int_mults: 1000,
            int_mult_bit_cycles: 8000, // 8-bit features
            ..Default::default()
        };
        assert!(m.accelerator(&low).compute_nj < m.accelerator(&high).compute_nj);
    }
}
