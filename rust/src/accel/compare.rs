//! End-to-end model simulation + the speedup/energy comparisons behind the
//! paper's Tables 1–2 "Speedup" column and Fig. 22.

use crate::graph::csr::Csr;
use crate::quant::mixed::BitsFile;

use super::energy::EnergyModel;
use super::simulator::{CycleStats, Simulator};

/// Workload description of one model inference: layer dims + per-map bits.
#[derive(Debug, Clone)]
pub struct ModelWorkload {
    /// (f_in, f_out) per matmul, in execution order
    pub matmuls: Vec<(usize, usize)>,
    /// per-matmul input bitwidths (one entry per node, length N); uniform
    /// baselines pass a constant vector
    pub bits: Vec<Vec<u8>>,
    /// feature dim entering each aggregation
    pub agg_dims: Vec<usize>,
    /// NNS group count (0 = node-level, no NNS search)
    pub nns_m: usize,
}

impl ModelWorkload {
    /// Build from an exported `.bits.bin` plus the model's layer dims.
    pub fn from_bits_file(
        bf: &BitsFile,
        matmul_dims: Vec<(usize, usize)>,
        nns_m: usize,
    ) -> ModelWorkload {
        let bits: Vec<Vec<u8>> = bf.maps.iter().map(|(b, _)| b.clone()).collect();
        let agg_dims = matmul_dims.iter().map(|&(fi, _)| fi).collect();
        ModelWorkload {
            matmuls: matmul_dims,
            bits,
            agg_dims,
            nns_m,
        }
    }

    /// Uniform-bitwidth clone (the DQ-INT4 / arbitrary-b baselines).
    pub fn with_uniform_bits(&self, b: u8) -> ModelWorkload {
        let mut w = self.clone();
        for bits in w.bits.iter_mut() {
            for x in bits.iter_mut() {
                *x = b;
            }
        }
        w
    }
}

/// Simulate a full model inference over `csr`.
pub fn simulate_model_cycles(
    sim: &Simulator,
    csr: &Csr,
    workload: &ModelWorkload,
) -> CycleStats {
    let mut total = CycleStats::default();
    let n = csr.num_nodes();
    for (li, &(f_in, f_out)) in workload.matmuls.iter().enumerate() {
        let uniform4 = vec![4u8; n];
        let bits = workload
            .bits
            .get(li)
            .map(|b| {
                if b.len() == n {
                    b.clone()
                } else if b.is_empty() {
                    uniform4.clone()
                } else {
                    // NNS groups: expand by cycling (distribution preserved)
                    (0..n).map(|i| b[i % b.len()]).collect()
                }
            })
            .unwrap_or(uniform4);
        if workload.nns_m > 0 {
            total.add(&sim.nns_phase(n, f_in, workload.nns_m));
        }
        total.add(&sim.update_phase(&bits, f_in, f_out));
        let agg_dim = workload.agg_dims.get(li).copied().unwrap_or(f_out);
        total.add(&sim.aggregate_phase(csr, agg_dim));
    }
    total
}

/// Speedup of a mixed-precision model vs the DQ-INT4 baseline on the same
/// graph — the Tables 1–2 "Speedup" definition (DQ = 1×).
pub fn speedup_vs_dq(sim: &Simulator, csr: &Csr, workload: &ModelWorkload) -> f64 {
    let ours = simulate_model_cycles(sim, csr, workload).total_cycles();
    let dq = simulate_model_cycles(sim, csr, &workload.with_uniform_bits(4)).total_cycles();
    if ours == 0 {
        return 0.0;
    }
    dq as f64 / ours as f64
}

/// Fig. 22: energy-efficiency ratio vs the fp32-GPU model.
pub fn energy_efficiency_vs_gpu(
    sim: &Simulator,
    csr: &Csr,
    workload: &ModelWorkload,
) -> f64 {
    let stats = simulate_model_cycles(sim, csr, workload);
    EnergyModel::default().efficiency_vs_gpu(&stats)
}

/// Fixed-vs-float op-count ratio (Table 6).
pub fn float_op_ratio(sim: &Simulator, csr: &Csr, workload: &ModelWorkload) -> (u64, u64, f64) {
    let s = simulate_model_cycles(sim, csr, workload);
    let fixed = s.int_mults + s.int_adds;
    let ratio = s.float_ops as f64 / fixed.max(1) as f64;
    (fixed, s.float_ops, ratio)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::config::AccelConfig;
    use crate::util::rng::Rng;

    fn ba_graph(n: usize) -> Csr {
        let mut rng = Rng::new(7);
        crate::graph::generate::preferential_attachment(&mut rng, n, 2)
    }

    /// Power-law bits: low degree → low bits (the learned pattern).
    fn degree_bits(csr: &Csr) -> Vec<u8> {
        (0..csr.num_nodes())
            .map(|v| match csr.in_degree(v) {
                0..=3 => 2u8,
                4..=8 => 3,
                9..=20 => 5,
                _ => 8,
            })
            .collect()
    }

    fn workload(csr: &Csr) -> ModelWorkload {
        let bits = degree_bits(csr);
        ModelWorkload {
            matmuls: vec![(128, 64), (64, 16)],
            bits: vec![bits.clone(), bits],
            agg_dims: vec![64, 16],
            nns_m: 0,
        }
    }

    #[test]
    fn speedup_in_paper_range() {
        let csr = ba_graph(3000);
        let sim = Simulator::new(AccelConfig::default());
        let s = speedup_vs_dq(&sim, &csr, &workload(&csr));
        // paper reports 1.2x–2.0x for learned bits vs DQ-INT4
        assert!(s > 1.1 && s < 3.0, "speedup {s}");
    }

    #[test]
    fn uniform_4bit_speedup_is_one() {
        let csr = ba_graph(1000);
        let sim = Simulator::new(AccelConfig::default());
        let w = workload(&csr).with_uniform_bits(4);
        let s = speedup_vs_dq(&sim, &csr, &w);
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn higher_bits_slower() {
        let csr = ba_graph(1000);
        let sim = Simulator::new(AccelConfig::default());
        let w8 = workload(&csr).with_uniform_bits(8);
        let s = speedup_vs_dq(&sim, &csr, &w8);
        assert!(s < 1.0, "8-bit should be slower than 4-bit: {s}");
    }

    #[test]
    fn energy_efficiency_positive_and_large() {
        let csr = ba_graph(1000);
        let sim = Simulator::new(AccelConfig::default());
        let e = energy_efficiency_vs_gpu(&sim, &csr, &workload(&csr));
        assert!(e > 2.0, "efficiency {e}");
    }

    #[test]
    fn float_ratio_matches_table6_shape() {
        // Table 6: float ops < 1% of fixed ops
        let csr = ba_graph(2000);
        let sim = Simulator::new(AccelConfig::default());
        let mut w = workload(&csr);
        w.nns_m = 1000;
        let (_fixed, _float, ratio) = float_op_ratio(&sim, &csr, &w);
        // paper's Table 6 reports 0.34%–0.98% at their (larger) feature
        // dims; the ratio scales ~1/F, so this small config allows 5%.
        assert!(ratio < 0.05, "ratio {ratio}");
    }

    #[test]
    fn nns_overhead_under_one_percent_of_cycles() {
        // §5.4: NNS adds ~0.95% latency
        let csr = ba_graph(2000);
        let sim = Simulator::new(AccelConfig::default());
        let base = simulate_model_cycles(&sim, &csr, &workload(&csr)).total_cycles();
        let mut w = workload(&csr);
        w.nns_m = 1000;
        let with_nns = simulate_model_cycles(&sim, &csr, &w).total_cycles();
        let overhead = with_nns as f64 / base as f64 - 1.0;
        assert!(overhead < 0.02, "overhead {overhead}");
    }
}
