//! Deterministic cycle accounting for the bit-serial dataflow.

use crate::graph::csr::Csr;

use super::config::AccelConfig;

/// Cycle/traffic counters accumulated over one simulated inference.
#[derive(Debug, Clone, Default)]
pub struct CycleStats {
    pub update_cycles: u64,
    pub aggregate_cycles: u64,
    /// integer multiply count (bit-serial mults, counted once per MAC op)
    pub int_mults: u64,
    /// weighted by feature bits: Σ bits over all serialized mults
    pub int_mult_bit_cycles: u64,
    pub int_adds: u64,
    /// float ops on the rescale/NNS path (element-wise, Table 6)
    pub float_ops: u64,
    /// on-chip SRAM traffic in bytes
    pub sram_bytes: u64,
    /// off-chip (HBM) traffic in bytes
    pub hbm_bytes: u64,
}

impl CycleStats {
    pub fn total_cycles(&self) -> u64 {
        self.update_cycles + self.aggregate_cycles
    }

    pub fn add(&mut self, other: &CycleStats) {
        self.update_cycles += other.update_cycles;
        self.aggregate_cycles += other.aggregate_cycles;
        self.int_mults += other.int_mults;
        self.int_mult_bit_cycles += other.int_mult_bit_cycles;
        self.int_adds += other.int_adds;
        self.float_ops += other.float_ops;
        self.sram_bytes += other.sram_bytes;
        self.hbm_bytes += other.hbm_bytes;
    }
}

/// The simulator: stateless w.r.t. data values (cycle counts depend only on
/// shapes, bits and graph structure — the dataflow is statically scheduled).
#[derive(Debug, Clone)]
pub struct Simulator {
    pub cfg: AccelConfig,
}

impl Simulator {
    pub fn new(cfg: AccelConfig) -> Self {
        Simulator { cfg }
    }

    /// Update phase `B = X·W` with per-node feature bitwidths.
    ///
    /// Tiles of `pes` rows run in lockstep; each of the `f_out` weight
    /// columns costs `ceil(f_in / macs_per_pe) · max(bits in tile)` cycles
    /// (the bit-serial multiplier streams feature bits, weights are 4-bit
    /// parallel).  With `bit_sorted_schedule`, rows are grouped by
    /// bitwidth first, shrinking the lockstep max.
    pub fn update_phase(&self, bits: &[u8], f_in: usize, f_out: usize) -> CycleStats {
        let mut stats = CycleStats::default();
        if bits.is_empty() || f_in == 0 || f_out == 0 {
            return stats;
        }
        let mut order: Vec<u8> = bits.to_vec();
        if self.cfg.bit_sorted_schedule {
            order.sort_unstable_by(|a, b| b.cmp(a));
        }
        let chunks = f_in.div_ceil(self.cfg.macs_per_pe) as u64;
        for tile in order.chunks(self.cfg.pes) {
            let max_bits = *tile.iter().max().unwrap() as u64;
            stats.update_cycles += chunks * max_bits * f_out as u64;
            // ops accounting (per real MAC, not per lockstep slot)
            for &b in tile {
                stats.int_mults += (f_in * f_out) as u64;
                stats.int_mult_bit_cycles += (f_in * f_out) as u64 * b as u64;
                stats.int_adds += (f_in * f_out) as u64;
            }
        }
        // Eq. 2 rescale: one float multiply per output element
        stats.float_ops += (bits.len() * f_out) as u64;
        // SRAM traffic: read X (packed bits), read W once per tile pass,
        // write B (assume 8-bit stored codes for B)
        let x_bytes: u64 = bits.iter().map(|&b| (b as u64 * f_in as u64).div_ceil(8)).sum();
        let w_bytes = (f_in * f_out) as u64 * self.cfg.weight_bits as u64 / 8;
        let out_bytes = (bits.len() * f_out) as u64;
        stats.sram_bytes += x_bytes + w_bytes + out_bytes;
        // spills: weights over the weight buffer re-stream per row tile
        if w_bytes > self.cfg.weight_buf as u64 {
            let tiles = bits.len().div_ceil(self.cfg.pes) as u64;
            stats.hbm_bytes += (w_bytes - self.cfg.weight_buf as u64) * tiles.max(1);
        }
        if x_bytes > self.cfg.input_buf as u64 {
            stats.hbm_bytes += x_bytes - self.cfg.input_buf as u64;
        }
        stats
    }

    /// Aggregation phase `X' = Â·B` over a CSR (fixed-point adds only; Â is
    /// never quantized, Proof 2).  Zero-degree rows are eliminated (CSR).
    pub fn aggregate_phase(&self, csr: &Csr, f: usize) -> CycleStats {
        let mut stats = CycleStats::default();
        let mut degrees: Vec<u32> = (0..csr.num_nodes())
            .map(|v| csr.in_degree(v) as u32)
            .filter(|&d| d > 0)
            .collect();
        if self.cfg.degree_sorted_schedule {
            degrees.sort_unstable_by(|a, b| b.cmp(a));
        }
        let chunks = f.div_ceil(self.cfg.macs_per_pe) as u64;
        for group in degrees.chunks(self.cfg.pes) {
            let max_deg = *group.iter().max().unwrap() as u64;
            stats.aggregate_cycles += max_deg * chunks;
            for &d in group {
                stats.int_adds += d as u64 * f as u64;
            }
        }
        // degree-normalisation / step-size rescale: element-wise floats
        stats.float_ops += (csr.num_nodes() * f) as u64;
        // traffic: edges (CSR u32) + gathered rows
        let edge_bytes = (csr.num_edges() * 4) as u64;
        stats.sram_bytes += edge_bytes + (csr.num_edges() * f) as u64;
        if edge_bytes > self.cfg.edge_buf as u64 {
            stats.hbm_bytes += edge_bytes - self.cfg.edge_buf as u64;
        }
        stats
    }

    /// NNS selection overhead (graph-level): one comparator-array search
    /// (log2 m steps, overlapped in the paper's pipeline) + one float
    /// multiply per feature for the re-quantize (Table 6 accounting).
    pub fn nns_phase(&self, num_nodes: usize, f: usize, m: usize) -> CycleStats {
        let mut stats = CycleStats::default();
        let search_steps = (m.max(2) as f64).log2().ceil() as u64;
        // comparator array: `pes` nodes searched in parallel
        stats.update_cycles += num_nodes.div_ceil(self.cfg.pes) as u64 * search_steps;
        stats.float_ops += (num_nodes * f * 2) as u64; // dequant+requant muls
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{property, Gen};
    use crate::util::rng::Rng;

    fn sim() -> Simulator {
        Simulator::new(AccelConfig::default())
    }

    #[test]
    fn update_cycles_formula_single_tile() {
        // 256 nodes, all 4-bit, f_in=16 (1 chunk), f_out=8:
        // cycles = 1 chunk * 4 bits * 8 cols = 32
        let bits = vec![4u8; 256];
        let s = sim().update_phase(&bits, 16, 8);
        assert_eq!(s.update_cycles, 32);
    }

    #[test]
    fn lockstep_max_governs_tile() {
        // one 8-bit node among 255 2-bit nodes: unsorted single tile costs
        // the max (8)
        let mut bits = vec![2u8; 256];
        bits[0] = 8;
        let cfg = AccelConfig::unsorted();
        let s = Simulator::new(cfg).update_phase(&bits, 16, 1);
        assert_eq!(s.update_cycles, 8);
    }

    #[test]
    fn bit_sorting_reduces_cycles() {
        // mixed bits across 2 tiles: sorted schedule packs high bits
        // together
        let mut bits = Vec::new();
        for i in 0..512 {
            bits.push(if i % 2 == 0 { 8u8 } else { 2u8 });
        }
        let sorted = sim().update_phase(&bits, 16, 4);
        let unsorted = Simulator::new(AccelConfig::unsorted()).update_phase(&bits, 16, 4);
        // unsorted: both tiles max=8 => 2*8; sorted: 8 + 2 => 10
        assert!(sorted.update_cycles < unsorted.update_cycles);
        assert_eq!(sorted.update_cycles, (8 + 2) * 4);
        assert_eq!(unsorted.update_cycles, (8 + 8) * 4);
    }

    #[test]
    fn cycles_monotone_in_bits_property() {
        property("update cycles monotone in bits", 30, |g: &mut Gen| {
            let n = g.usize_range(1, 600);
            let f_in = g.usize_range(1, 200);
            let f_out = g.usize_range(1, 64);
            let bits: Vec<u8> = (0..n).map(|_| g.usize_range(1, 8) as u8).collect();
            let plus: Vec<u8> = bits.iter().map(|&b| (b + 1).min(8)).collect();
            let a = sim().update_phase(&bits, f_in, f_out).update_cycles;
            let b = sim().update_phase(&plus, f_in, f_out).update_cycles;
            assert!(b >= a);
        });
    }

    #[test]
    fn dq4_vs_mixed_speedup_shape() {
        // power-law-ish bits: most nodes 2-bit, few 8-bit → faster than
        // uniform 4-bit under the sorted schedule
        let mut bits = vec![2u8; 2000];
        for b in bits.iter_mut().take(50) {
            *b = 8;
        }
        let mixed = sim().update_phase(&bits, 128, 64).update_cycles;
        let dq = sim().update_phase(&vec![4u8; 2000], 128, 64).update_cycles;
        // 2000 nodes = 8 lockstep tiles; one tile pays the 8-bit tail →
        // ideal = 4·8 / (8 + 2·7) ≈ 1.45
        assert!(
            dq as f64 / mixed as f64 > 1.4,
            "speedup {}",
            dq as f64 / mixed as f64
        );
    }

    #[test]
    fn aggregation_sorted_balances_load() {
        let mut rng = Rng::new(0);
        let csr = crate::graph::generate::preferential_attachment(&mut rng, 3000, 2);
        let sorted = sim().aggregate_phase(&csr, 64).aggregate_cycles;
        let unsorted = Simulator::new(AccelConfig::unsorted())
            .aggregate_phase(&csr, 64)
            .aggregate_cycles;
        assert!(sorted <= unsorted);
    }

    #[test]
    fn aggregation_add_count_exact() {
        let csr = Csr::from_edges(3, &[(0, 1), (1, 0), (1, 2), (2, 1)]).unwrap();
        let s = sim().aggregate_phase(&csr, 8);
        // 4 edges * 8 dims adds
        assert_eq!(s.int_adds, 32);
    }

    #[test]
    fn nns_overhead_is_small_fraction() {
        // Table 6 shape: float ops ≪ fixed-point ops for a real layer
        let s_nns = sim().nns_phase(1000, 64, 1000);
        let bits = vec![4u8; 1000];
        let s_up = sim().update_phase(&bits, 64, 64);
        let ratio = s_nns.float_ops as f64 / s_up.int_mults as f64;
        assert!(ratio < 0.05, "float ratio {ratio}");
    }

    #[test]
    fn empty_inputs() {
        let s = sim().update_phase(&[], 16, 16);
        assert_eq!(s.total_cycles(), 0);
    }
}
