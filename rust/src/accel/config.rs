//! Accelerator architecture parameters (paper §A.7.5 defaults).

/// Static architecture description.
#[derive(Debug, Clone)]
pub struct AccelConfig {
    /// Processing engines (rows processed in lockstep per phase).
    pub pes: usize,
    /// Bit-serial MACs per PE (feature dims processed per chunk).
    pub macs_per_pe: usize,
    /// Weight bitwidth (fixed 4 in the paper).
    pub weight_bits: u8,
    /// Input buffer bytes (2 MB).
    pub input_buf: usize,
    /// Output buffer bytes (2 MB).
    pub output_buf: usize,
    /// Edge buffer bytes (256 KB).
    pub edge_buf: usize,
    /// Weight buffer bytes (256 KB).
    pub weight_buf: usize,
    /// Sort nodes by in-degree before aggregation (the paper's
    /// load-balancing optimisation).  Exposed for the ablation bench.
    pub degree_sorted_schedule: bool,
    /// Sort nodes by bitwidth before the update phase (groups nodes of
    /// similar precision into the same lockstep tile; the bit-serial
    /// analogue of the degree sort — paper processes "nodes with similar
    /// in-degrees in parallel", and bits track degree).
    pub bit_sorted_schedule: bool,
}

impl Default for AccelConfig {
    fn default() -> Self {
        AccelConfig {
            pes: 256,
            macs_per_pe: 16,
            weight_bits: 4,
            input_buf: 2 << 20,
            output_buf: 2 << 20,
            edge_buf: 256 << 10,
            weight_buf: 256 << 10,
            degree_sorted_schedule: true,
            bit_sorted_schedule: true,
        }
    }
}

impl AccelConfig {
    /// Unoptimized variant (no scheduling sorts) for ablations.
    pub fn unsorted() -> Self {
        AccelConfig {
            degree_sorted_schedule: false,
            bit_sorted_schedule: false,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = AccelConfig::default();
        assert_eq!(c.pes, 256);
        assert_eq!(c.macs_per_pe, 16);
        assert_eq!(c.weight_bits, 4);
        assert_eq!(c.input_buf, 2 * 1024 * 1024);
        assert_eq!(c.edge_buf, 256 * 1024);
    }
}
