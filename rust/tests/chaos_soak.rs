//! Seeded-chaos soak: the PR 8–10 recovery claims as falsifiable
//! properties under deterministic fault injection.
//!
//! Each test arms a pinned `util::fault` schedule against an in-process
//! serving stack (synthetic native session, supervised runners, circuit
//! breakers, TCP front end) and asserts the contracts that the fault-free
//! suites can only claim:
//!
//! * **exactly-once accounting** — every offered request resolves to
//!   exactly one outcome class (`ok`/`rejected`/`errors`/`io_errors`),
//!   with retries counted separately;
//! * **bounded restarts** — runner panics respawn within the configured
//!   budget, and budget exhaustion degrades to explicit rejections;
//! * **breaker lifecycle** — open → half-open probe → closed, surfaced
//!   in metrics;
//! * **bitwise parity** — the post-chaos resident state equals a
//!   fault-free session fed exactly the acknowledged deltas.
//!
//! Fault arming is process-global, so every test serializes on one lock
//! and disarms on drop (panic-safe).  On failure each assertion message
//! carries the one-line `A2Q_FAULTS=<seed>:<spec>` replay string.
//! Setting `A2Q_FAULTS` in the environment overrides the pinned soak
//! schedules with yours.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use a2q::coordinator::net::{
    run_load, LoadConfig, NetClient, NetConfig, NetServer, RetryPolicy, WireRequest,
    WireResponse,
};
use a2q::coordinator::{
    synthetic_node_session, BatchExecutor, BatcherConfig, Coordinator, NativeExecutor, Payload,
    SuperviseConfig,
};
use a2q::graph::delta::GraphDelta;
use a2q::util::fault;

/// Synthetic session shape shared by the faulted server and the
/// fault-free parity reference.
const NODES: usize = 32;
const SESSION_SEED: u64 = 7;

/// Serializes fault arming across tests (the schedule is process-global).
static ARM_LOCK: Mutex<()> = Mutex::new(());

/// Holds the arm lock and guarantees `fault::disarm()` on drop, so a
/// failing test cannot leak its schedule into the next one.
struct Armed {
    _guard: MutexGuard<'static, ()>,
    replay: String,
}

impl Armed {
    fn new(seed: u64, spec: &str) -> Armed {
        let guard = ARM_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        fault::arm(seed, spec).expect("arm fault schedule");
        Armed {
            _guard: guard,
            replay: format!("A2Q_FAULTS={seed}:{spec}"),
        }
    }
}

impl Drop for Armed {
    fn drop(&mut self) {
        fault::disarm();
    }
}

fn batcher() -> BatcherConfig {
    BatcherConfig {
        node_budget: 4096,
        graph_slots: 8,
        max_wait: Duration::from_micros(500),
        queue_cap: 64,
        adaptive_wait: None,
    }
}

/// Coordinator over a deterministic native session; returns the model
/// name the session registers under.
fn synthetic_coordinator(sup: SuperviseConfig) -> (Coordinator, String) {
    let (model, ds) = synthetic_node_session(NODES, SESSION_SEED).expect("synthetic session");
    let name = model.name.clone();
    let exec = NativeExecutor::new(model, Some(&ds)).expect("native executor");
    let mut coord = Coordinator::new();
    coord.set_supervision(sup);
    coord.add_model(&name, Arc::new(exec), batcher());
    (coord, name)
}

/// Deterministic edge-only delta `i` (node count stays fixed so parity
/// classifies the same id range; duplicate adds merge idempotently).
fn edge_delta(i: u32) -> GraphDelta {
    let n = NODES as u32;
    let src = (i * 3 + 1) % n;
    let dst = (src + 7) % n;
    GraphDelta {
        add_edges: vec![(src, dst), (dst, src)],
        ..Default::default()
    }
}

/// Classify every node over the wire; logits as bit patterns for exact
/// comparison.  Retries through a breaker that is still cooling down
/// from the chaos phase (the successful probe closes it).
fn classify_bits_net(client: &mut NetClient, model: &str) -> Vec<Vec<u32>> {
    let req = WireRequest::Classify {
        model: model.to_string(),
        nodes: (0..NODES as u32).collect(),
    };
    let policy = RetryPolicy {
        max_retries: 20,
        base_backoff: Duration::from_millis(5),
        deadline: Some(Duration::from_secs(10)),
        ..RetryPolicy::default()
    };
    match client
        .request_with_retry(&req, &policy)
        .expect("post-chaos classify")
    {
        WireResponse::Ok { predictions, .. } => predictions
            .iter()
            .map(|p| p.output.iter().map(|v| v.to_bits()).collect())
            .collect(),
        other => panic!("post-chaos classify failed: {other:?}"),
    }
}

/// The soak schedules: two pinned seeds, or the operator's
/// `A2Q_FAULTS=<seed>:<spec>` override for replaying a failure.
fn soak_schedules() -> Vec<(u64, String)> {
    const SPEC: &str = "executor.update=err@0.25;executor.classify=err@0.2;runner.poll=panic@0.003";
    if let Ok(raw) = std::env::var("A2Q_FAULTS") {
        if let Some((seed, spec)) = raw.split_once(':') {
            if let Ok(seed) = seed.trim().parse::<u64>() {
                eprintln!("chaos_soak: using operator schedule from A2Q_FAULTS");
                return vec![(seed, spec.to_string())];
            }
        }
    }
    vec![(42, SPEC.to_string()), (1337, SPEC.to_string())]
}

/// The tentpole property: under seeded executor faults + runner panics,
/// a mixed read/write load loses nothing — every request is accounted
/// for exactly once, restarts stay within budget, and the surviving
/// resident state is bitwise-identical to a fault-free session fed the
/// acknowledged deltas in order.
#[test]
fn seeded_soak_exactly_once_and_bitwise_parity() {
    for (seed, spec) in soak_schedules() {
        let armed = Armed::new(seed, &spec);
        let replay = armed.replay.clone();
        eprintln!("chaos_soak: soaking under {replay}");

        let sup = SuperviseConfig {
            restart_budget: 100,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(20),
            breaker_threshold: 4,
            breaker_cooldown: Duration::from_millis(40),
        };
        let (coord, model) = synthetic_coordinator(sup);
        let srv = NetServer::start(coord, NetConfig::default()).expect("start server");
        let addr = format!("{}", srv.local_addr());

        // mixed clients: retrying readers race the sequential updater
        let load = {
            let addr = addr.clone();
            let model = model.clone();
            std::thread::spawn(move || {
                run_load(
                    &addr,
                    &LoadConfig {
                        conns: 4,
                        requests_per_conn: 50,
                        model,
                        nodes_per_req: 2,
                        node_space: NODES as u32,
                        pace: Duration::ZERO,
                        retry: RetryPolicy {
                            max_retries: 6,
                            base_backoff: Duration::from_millis(5),
                            deadline: Some(Duration::from_secs(5)),
                            ..RetryPolicy::default()
                        },
                    },
                )
            })
        };

        // single sequential updater.  The update path is atomic
        // (validate + staged apply before commit) and injected update
        // faults fire *before* the mutation, so an `Ok` reply means
        // applied and an `Error`/`Rejected` reply means not applied —
        // the acked list below is the exact mutation history.
        let mut client = NetClient::connect(&addr).expect("updater connect");
        let mut acked: Vec<GraphDelta> = Vec::new();
        for i in 0..16u32 {
            let delta = edge_delta(i);
            match client.request(&WireRequest::Update {
                model: model.clone(),
                delta: delta.clone(),
            }) {
                Ok(WireResponse::Ok { .. }) => acked.push(delta),
                Ok(WireResponse::Error { .. }) | Ok(WireResponse::Rejected { .. }) => {}
                Ok(other) => panic!("unexpected update reply {other:?}; replay {replay}"),
                Err(e) => panic!("updater transport failed: {e}; replay {replay}"),
            }
            std::thread::sleep(Duration::from_millis(2));
        }

        let report = load
            .join()
            .expect("load thread")
            .unwrap_or_else(|e| panic!("load run failed: {e}; replay {replay}"));

        // exactly-once accounting: every offered request resolved to one
        // outcome class; no transport drops (faults surface on-protocol)
        assert_eq!(
            report.ok + report.rejected + report.errors + report.io_errors,
            report.sent,
            "lost replies under chaos: {report:?}; replay {replay}"
        );
        assert_eq!(
            report.io_errors, 0,
            "dropped connections under chaos: {report:?}; replay {replay}"
        );
        assert!(
            report.ok > 0,
            "nothing succeeded under chaos: {report:?}; replay {replay}"
        );

        // bounded restarts, visible in the metrics surface
        let metrics = srv.metrics_json();
        let restarts = metrics.req_f64("runner_restarts").expect("runner_restarts");
        assert!(
            restarts <= 100.0,
            "restart budget exceeded: {restarts}; replay {replay}"
        );

        // quiesce the faults, then read the surviving resident state
        drop(armed);
        let bits_chaos = classify_bits_net(&mut client, &model);
        let drained = srv.drain();
        assert_eq!(
            drained.unreplied_in_flight, 0,
            "drain lost admitted replies; replay {replay}"
        );

        // fault-free reference: a fresh session fed exactly the acked
        // deltas must reproduce the chaos survivor bit-for-bit
        let (reference, ref_model) = synthetic_coordinator(SuperviseConfig::default());
        for delta in &acked {
            reference
                .submit_blocking(&ref_model, Payload::UpdateGraph(delta.clone()))
                .unwrap_or_else(|e| panic!("reference replay failed: {e}; replay {replay}"));
        }
        let resp = reference
            .submit_blocking(&ref_model, Payload::ClassifyNodes((0..NODES as u32).collect()))
            .expect("reference classify");
        let bits_ref: Vec<Vec<u32>> = resp
            .predictions
            .iter()
            .map(|p| p.output.iter().map(|v| v.to_bits()).collect())
            .collect();
        assert_eq!(
            bits_chaos, bits_ref,
            "post-chaos logits diverge from the acked-delta replay \
             ({} acked delta(s)); replay {replay}",
            acked.len()
        );
        reference.shutdown();
        eprintln!(
            "chaos_soak: seed {seed} ok — {} ok / {} rejected / {} errors / {} retries, \
             {restarts} restart(s), {} acked delta(s)",
            report.ok, report.rejected, report.errors, report.retries, acked.len()
        );
    }
}

/// Reply-write faults: the connection drops mid-reply, and retrying
/// clients reconnect and resend until the answer lands.  Idempotent
/// reads only — a lost reply is indistinguishable from a lost request.
#[test]
fn write_faults_recovered_by_reconnecting_retries() {
    let armed = Armed::new(9001, "net.write_frame=err@0.3");
    let replay = armed.replay.clone();
    let (coord, model) = synthetic_coordinator(SuperviseConfig::default());
    let srv = NetServer::start(coord, NetConfig::default()).expect("start server");
    let report = run_load(
        &format!("{}", srv.local_addr()),
        &LoadConfig {
            conns: 2,
            requests_per_conn: 25,
            model,
            nodes_per_req: 2,
            node_space: NODES as u32,
            pace: Duration::ZERO,
            retry: RetryPolicy {
                max_retries: 8,
                base_backoff: Duration::from_millis(2),
                deadline: Some(Duration::from_secs(5)),
                ..RetryPolicy::default()
            },
        },
    )
    .expect("load run");
    assert_eq!(
        report.ok + report.rejected + report.errors + report.io_errors,
        report.sent,
        "{report:?}; replay {replay}"
    );
    assert!(
        report.ok > 0,
        "no request survived write faults: {report:?}; replay {replay}"
    );
    assert!(
        report.retries > 0,
        "write faults at 0.3 must force retries: {report:?}; replay {replay}"
    );
    drop(armed);
    srv.drain();
}

/// Runner panics respawn within the budget; once the schedule is
/// disarmed the respawned runner serves again.
#[test]
fn runner_respawns_within_budget_and_recovers() {
    let armed = Armed::new(3, "runner.poll=panic@1.0");
    let replay = armed.replay.clone();
    let sup = SuperviseConfig {
        restart_budget: 50,
        backoff_base: Duration::from_millis(2),
        backoff_cap: Duration::from_millis(20),
        ..SuperviseConfig::default()
    };
    let (coord, model) = synthetic_coordinator(sup);
    // wait until the supervisor has respawned at least twice
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let restarts = coord.metrics().runner_restarts;
        if restarts >= 2 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "no respawns after 10s (restarts={restarts}); replay {replay}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(armed); // disarm: the next respawn iteration runs clean
    let resp = coord
        .submit_blocking(&model, Payload::ClassifyNodes(vec![0, 1]))
        .unwrap_or_else(|e| panic!("respawned runner must serve: {e}; replay {replay}"));
    assert_eq!(resp.predictions.len(), 2);
    let restarts = coord.metrics().runner_restarts;
    assert!(
        (2..=50).contains(&restarts),
        "restarts out of bounds: {restarts}; replay {replay}"
    );
    coord.shutdown();
}

/// Budget exhaustion is a terminal, explicit state: the runner stops
/// respawning and later submits are rejected as stopped — never a hang.
#[test]
fn restart_budget_exhaustion_degrades_to_rejections() {
    let armed = Armed::new(4, "runner.poll=panic@1.0");
    let replay = armed.replay.clone();
    let sup = SuperviseConfig {
        restart_budget: 2,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(5),
        ..SuperviseConfig::default()
    };
    let (coord, model) = synthetic_coordinator(sup);
    // the runner burns its 2 respawns, then gives up and drops its queue
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match coord.submit_blocking(&model, Payload::ClassifyNodes(vec![0])) {
            Err(e) => {
                let msg = format!("{e}");
                if msg.contains("stopped") {
                    break;
                }
            }
            Ok(_) => {}
        }
        assert!(
            Instant::now() < deadline,
            "exhausted runner never became a stopped rejection; replay {replay}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(
        coord.metrics().runner_restarts,
        2,
        "exactly the budgeted respawns must have happened; replay {replay}"
    );
    drop(armed);
    coord.shutdown();
}

/// Breaker lifecycle under total executor failure: consecutive failed
/// batches open it, open rejects fast with a retry hint, and after the
/// cooldown a successful half-open probe closes it again.
#[test]
fn breaker_opens_then_probe_closes_after_faults_clear() {
    let armed = Armed::new(5, "executor.classify=err@1.0");
    let replay = armed.replay.clone();
    let sup = SuperviseConfig {
        breaker_threshold: 2,
        breaker_cooldown: Duration::from_millis(100),
        ..SuperviseConfig::default()
    };
    let (coord, model) = synthetic_coordinator(sup);
    // every batch fails: two serial submits trip the threshold
    let deadline = Instant::now() + Duration::from_secs(10);
    while coord.breaker_state(&model) != Some("open") {
        let _ = coord.submit_blocking(&model, Payload::ClassifyNodes(vec![0]));
        assert!(
            Instant::now() < deadline,
            "breaker never opened under total failure; replay {replay}"
        );
    }
    // open = fast rejection carrying the breaker reason
    let err = coord
        .submit_blocking(&model, Payload::ClassifyNodes(vec![0]))
        .expect_err("open breaker must reject");
    assert!(
        format!("{err}").contains("circuit breaker open"),
        "got '{err}'; replay {replay}"
    );
    assert!(coord.metrics().breaker_opens >= 1);

    // faults clear; past the cooldown one probe closes the breaker
    drop(armed);
    std::thread::sleep(Duration::from_millis(150));
    let resp = coord
        .submit_blocking(&model, Payload::ClassifyNodes(vec![0, 1]))
        .unwrap_or_else(|e| panic!("half-open probe must pass: {e}; replay {replay}"));
    assert_eq!(resp.predictions.len(), 2);
    let deadline = Instant::now() + Duration::from_secs(5);
    while coord.breaker_state(&model) != Some("closed") {
        assert!(
            Instant::now() < deadline,
            "breaker never closed after successful probe; replay {replay}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    coord.shutdown();
}

/// WAL-append faults reject the delta before commit: the resident state
/// and the logits are untouched, and the same delta applies cleanly once
/// the schedule is disarmed.
#[test]
fn wal_append_fault_rejects_delta_without_corruption() {
    let armed = Armed::new(6, "persist.wal_append=err@1.0");
    let replay = armed.replay.clone();
    let dir = std::env::temp_dir().join(format!("a2q_chaos_wal_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let (model, ds) = synthetic_node_session(NODES, SESSION_SEED).expect("synthetic session");
    let exec = NativeExecutor::new(model, Some(&ds)).expect("native executor");
    let cfg = a2q::runtime::PersistConfig::new(&dir);
    let (exec, _report) = exec.with_persistence(cfg).expect("attach persistence");

    let before = exec.run_node_batch(&[0, 1, 2]).expect("pre-fault classify");
    let err = exec
        .apply_delta(&edge_delta(0))
        .expect_err("armed wal_append must reject the delta");
    assert!(
        format!("{err}").contains("injected fault"),
        "got '{err}'; replay {replay}"
    );
    let after = exec.run_node_batch(&[0, 1, 2]).expect("post-fault classify");
    let bits = |rows: &[Vec<f32>]| -> Vec<Vec<u32>> {
        rows.iter()
            .map(|r| r.iter().map(|v| v.to_bits()).collect())
            .collect()
    };
    assert_eq!(
        bits(&before),
        bits(&after),
        "rejected delta mutated resident state; replay {replay}"
    );

    drop(armed);
    exec.apply_delta(&edge_delta(0))
        .unwrap_or_else(|e| panic!("disarmed delta must apply: {e}; replay {replay}"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// With no schedule armed and no `A2Q_FAULTS`, every site is inert: the
/// full serve path behaves exactly as the fault-free suites assert.
#[test]
fn sites_inert_when_nothing_armed() {
    if std::env::var("A2Q_FAULTS").is_ok() {
        eprintln!("chaos_soak: skipping inertness check (A2Q_FAULTS is set)");
        return;
    }
    let _guard = ARM_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::disarm();
    assert!(fault::active().is_none());
    let (coord, model) = synthetic_coordinator(SuperviseConfig::default());
    let srv = NetServer::start(coord, NetConfig::default()).expect("start server");
    let mut client = NetClient::connect(format!("{}", srv.local_addr())).expect("connect");
    match client.classify(&model, vec![0, 1, 2]).expect("classify") {
        WireResponse::Ok { predictions, .. } => assert_eq!(predictions.len(), 3),
        other => panic!("inert server must serve: {other:?}"),
    }
    let report = srv.drain();
    assert_eq!(report.unreplied_in_flight, 0);
}
