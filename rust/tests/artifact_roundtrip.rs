//! Integration: AOT artifacts ⇄ rust runtime ⇄ native inference.
//!
//! These tests require `make artifacts` to have run (they are skipped with
//! a notice otherwise, so `cargo test` stays green on a fresh checkout).

use a2q::gnn::{forward_fp, forward_int, GnnModel, GraphInput};
use a2q::graph::io::{load_named, Dataset};
use a2q::graph::norm::EdgeForm;
use a2q::quant::mixed::BitsFile;
use a2q::runtime::ArtifactIndex;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = a2q::artifacts_dir();
    if dir.join("models").join("index.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn index_lists_models_with_manifests() {
    let Some(dir) = artifacts() else { return };
    let index = ArtifactIndex::load(&dir).unwrap();
    assert!(!index.models.is_empty());
    for a in index.all().unwrap() {
        assert!(a.hlo_path.exists(), "{} missing hlo", a.name);
        assert!(a.out_dim > 0);
        assert!(!a.expected_head.is_empty());
    }
}

#[test]
fn native_fp_matches_python_export_record() {
    let Some(dir) = artifacts() else { return };
    let index = ArtifactIndex::load(&dir).unwrap();
    for name in &index.models {
        let artifact = index.artifact(name).unwrap();
        if !artifact.node_level {
            continue; // graph-level record depends on the export batch
        }
        let model = GnnModel::load(&index.dir, name).unwrap();
        let Dataset::Node(ds) = load_named(&dir, &artifact.dataset).unwrap() else {
            panic!("expected node dataset")
        };
        let ef = EdgeForm::from_csr(&ds.csr);
        let input = GraphInput::node_level(&ds.features, ds.num_features, &ef);
        let out = forward_fp(&model, &input);
        let head = &artifact.expected_head;
        let got: Vec<f32> = out.data[..head.len()].to_vec();
        for (i, (g, w)) in got.iter().zip(head).enumerate() {
            assert!(
                (g - w).abs() < 2e-2 + 0.05 * w.abs(),
                "{name} logit {i}: native {g} vs python {w}"
            );
        }
    }
}

#[test]
fn int_path_tracks_fp_path_on_artifact() {
    let Some(dir) = artifacts() else { return };
    let index = ArtifactIndex::load(&dir).unwrap();
    let Ok(artifact) = index.artifact("gcn-synth-cora-a2q") else {
        return;
    };
    let model = GnnModel::load(&index.dir, &artifact.name).unwrap();
    let Dataset::Node(ds) = load_named(&dir, &artifact.dataset).unwrap() else {
        panic!()
    };
    let ef = EdgeForm::from_csr(&ds.csr);
    let input = GraphInput::node_level(&ds.features, ds.num_features, &ef);
    let fp = forward_fp(&model, &input);
    let int = forward_int(&model, &input);
    // identical argmax on ≥99% of nodes (fp-emulation vs integer codes)
    let agree = fp
        .argmax_rows()
        .iter()
        .zip(int.argmax_rows())
        .filter(|(a, b)| **a == *b)
        .count();
    assert!(
        agree as f64 >= 0.99 * fp.rows as f64,
        "argmax agreement {agree}/{}",
        fp.rows
    );
}

#[test]
fn quantized_model_accuracy_matches_manifest() {
    let Some(dir) = artifacts() else { return };
    let index = ArtifactIndex::load(&dir).unwrap();
    let Ok(artifact) = index.artifact("gcn-synth-cora-a2q") else {
        return;
    };
    let model = GnnModel::load(&index.dir, &artifact.name).unwrap();
    let Dataset::Node(ds) = load_named(&dir, &artifact.dataset).unwrap() else {
        panic!()
    };
    let ef = EdgeForm::from_csr(&ds.csr);
    let input = GraphInput::node_level(&ds.features, ds.num_features, &ef);
    let out = forward_fp(&model, &input);
    let pred = out.argmax_rows();
    let mut good = 0usize;
    let mut total = 0usize;
    for v in 0..ds.num_nodes() {
        if ds.test_mask[v] {
            total += 1;
            if pred[v] as i32 == ds.labels[v] {
                good += 1;
            }
        }
    }
    let acc = good as f64 / total as f64;
    assert!(
        (acc - artifact.accuracy).abs() < 0.08,
        "native acc {acc} vs recorded {}",
        artifact.accuracy
    );
}

#[test]
fn bits_file_consistent_with_manifest_avg() {
    let Some(dir) = artifacts() else { return };
    let index = ArtifactIndex::load(&dir).unwrap();
    for name in &index.models {
        let artifact = index.artifact(name).unwrap();
        let Some(bits_path) = artifact.bits_path() else {
            continue;
        };
        if !bits_path.exists() {
            continue;
        }
        let bf = BitsFile::load(&bits_path).unwrap();
        // manifest avg_bits excludes the unquantized input (cora); allow
        // generous slack for that accounting difference
        assert!(
            (bf.avg_bits() - artifact.avg_bits).abs() < 1.5,
            "{name}: bits file {:.2} vs manifest {:.2}",
            bf.avg_bits(),
            artifact.avg_bits
        );
    }
}
