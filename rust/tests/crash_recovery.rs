//! Tier-2 (opt-in): crash-recovery across a real process boundary.
//!
//! Spawns the actual `a2q-serve` binary on a synthetic native session with
//! a durable state dir, mutates the resident graph over the wire, then
//! `kill -9`s the server **mid-load** and restarts it.  The restarted
//! process must serve bitwise-identical logits, and a post-restore load
//! run must lose zero replies (`io_errors == 0`: every request gets an
//! on-protocol answer).
//!
//! Gated behind `A2Q_CRASH_TEST=1` because it spawns/kills processes and
//! binds sockets — the CI crash-recovery leg sets the knob; a plain
//! `cargo test` self-skips.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use a2q::coordinator::net::{run_load, LoadConfig, NetClient, WireRequest, WireResponse};
use a2q::graph::delta::GraphDelta;

/// Model name `a2q-serve --synthetic` registers (see
/// `coordinator::executor::synthetic_node_session`).
const MODEL: &str = "synthetic-gcn";
/// `--synthetic` node count; the delta workload appends two more.
const BASE_NODES: u32 = 48;

fn state_dir() -> PathBuf {
    std::env::temp_dir().join(format!("a2q_crash_{}", std::process::id()))
}

/// `a2q-serve` child whose `Drop` is the crash injector: SIGKILL, no
/// drain, no WAL goodbye — exactly the failure the WAL must absorb.
struct Server {
    child: Child,
    addr: String,
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_server(dir: &Path) -> Server {
    let mut child = Command::new(env!("CARGO_BIN_EXE_a2q-serve"))
        .args([
            "--synthetic",
            "48",
            "--synthetic-seed",
            "42",
            "--listen",
            "127.0.0.1:0",
            "--duration-s",
            "0",
            "--state-dir",
        ])
        .arg(dir)
        .env("A2Q_FSYNC", "always")
        .env("A2Q_SNAPSHOT_EVERY", "3")
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn a2q-serve");
    // restore-then-listen: the "listening on" line only appears after any
    // recovery replay finished, so parsing it doubles as the ready gate
    let stdout = child.stdout.take().expect("piped stdout");
    let mut addr = None;
    for line in BufReader::new(stdout).lines() {
        let line = line.expect("read a2q-serve stdout");
        eprintln!("[a2q-serve] {line}");
        if let Some((_, rest)) = line.split_once("listening on ") {
            addr = Some(rest.trim().to_string());
            break;
        }
    }
    Server {
        child,
        addr: addr.expect("a2q-serve printed its listen address"),
    }
}

/// Classify every node in one request; logits as bit patterns so the
/// comparison is exact equality, not epsilon closeness.
fn classify_bits(addr: &str, nodes: u32) -> Vec<Vec<u32>> {
    let mut client = NetClient::connect(addr).expect("connect");
    match client.classify(MODEL, (0..nodes).collect()).expect("classify") {
        WireResponse::Ok { predictions, .. } => predictions
            .iter()
            .map(|p| p.output.iter().map(|v| v.to_bits()).collect())
            .collect(),
        other => panic!("classify failed: {other:?}"),
    }
}

/// Resident-graph mutations (node appends exercise the online NNS
/// assignment the snapshot must capture).
fn workload() -> Vec<GraphDelta> {
    vec![
        GraphDelta {
            add_edges: vec![(5, 0), (0, 5), (7, 3)],
            ..Default::default()
        },
        GraphDelta {
            add_nodes: 1,
            new_features: vec![0.2, -0.1, 0.4, -0.3],
            add_edges: vec![(48, 0), (0, 48)],
            ..Default::default()
        },
        GraphDelta {
            add_nodes: 1,
            new_features: vec![-0.25, 0.15, -0.05, 0.35],
            add_edges: vec![(49, 48), (48, 49), (49, 1)],
            ..Default::default()
        },
        GraphDelta {
            remove_edges: vec![(5, 0)],
            ..Default::default()
        },
    ]
}

#[test]
fn kill_nine_mid_load_then_restart_serves_identical_logits() {
    if std::env::var("A2Q_CRASH_TEST").is_err() {
        eprintln!("crash_recovery: skipped (set A2Q_CRASH_TEST=1 to run)");
        return;
    }
    let dir = state_dir();
    let _ = std::fs::remove_dir_all(&dir);

    let server = spawn_server(&dir);
    let mut client = NetClient::connect(&server.addr).expect("connect");
    for delta in workload() {
        match client
            .request(&WireRequest::Update {
                model: MODEL.to_string(),
                delta,
            })
            .expect("send update")
        {
            WireResponse::Ok { .. } => {}
            other => panic!("update rejected: {other:?}"),
        }
    }
    let nodes = BASE_NODES + 2;
    let want = classify_bits(&server.addr, nodes);

    // closed-loop read load sized to outlive the kill: every delta above
    // is already fsynced, so SIGKILL at any point here loses nothing the
    // server acknowledged
    let addr = server.addr.clone();
    let load = std::thread::spawn(move || {
        run_load(
            &addr,
            &LoadConfig {
                conns: 4,
                requests_per_conn: 1_000_000,
                model: MODEL.to_string(),
                nodes_per_req: 2,
                node_space: nodes,
                pace: Duration::ZERO,
                ..LoadConfig::default()
            },
        )
    });
    std::thread::sleep(Duration::from_millis(120));
    drop(server); // SIGKILL mid-load
    let report = load.join().expect("load thread").expect("load report");
    assert!(
        report.io_errors > 0,
        "the kill must land while load is in flight (got {report:?})"
    );

    // restart over the same artifact + state dir: recovery replay runs
    // before the listen line we block on
    let server = spawn_server(&dir);
    let got = classify_bits(&server.addr, nodes);
    assert_eq!(
        got, want,
        "restarted server must reproduce pre-kill logits bit-for-bit"
    );

    // the recovered process is a healthy server: a full load run loses
    // zero replies (refusals, if any, arrive on-protocol as `rejected`)
    let report = run_load(
        &server.addr,
        &LoadConfig {
            conns: 4,
            requests_per_conn: 100,
            model: MODEL.to_string(),
            nodes_per_req: 2,
            node_space: nodes,
            pace: Duration::ZERO,
            ..LoadConfig::default()
        },
    )
    .expect("post-restore load");
    assert_eq!(
        report.io_errors, 0,
        "lost replies after restore: {report:?}"
    );
    assert_eq!(report.sent, report.ok + report.rejected + report.errors);
    assert!(report.ok > 0, "restored server must serve: {report:?}");

    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}
