//! Integration: PJRT execution of the AOT HLO matches the python export
//! record and the native rust forward; the coordinator serves it end to
//! end.  Requires `make artifacts`.

use std::sync::Arc;
use std::time::Duration;

use a2q::coordinator::request::Payload;
use a2q::coordinator::{BatchExecutor, BatcherConfig, Coordinator, PjrtExecutor};
use a2q::gnn::{forward_fp, GnnModel, GraphInput};
use a2q::graph::io::{load_named, Dataset};
use a2q::graph::norm::EdgeForm;
use a2q::runtime::{ArtifactIndex, EngineHandle};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = a2q::artifacts_dir();
    if dir.join("models").join("index.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

/// Spawn the engine, skipping the test when only the offline stub backend
/// (platform "cpu-stub") is linked — it cannot execute HLO.
fn pjrt_engine() -> Option<EngineHandle> {
    let engine = EngineHandle::spawn().unwrap();
    if engine.platform().unwrap() == "cpu-stub" {
        eprintln!("skipping: PJRT backend is the offline stub");
        return None;
    }
    Some(engine)
}

#[test]
fn pjrt_executes_artifact_and_matches_python() {
    let Some(dir) = artifacts() else { return };
    let index = ArtifactIndex::load(&dir).unwrap();
    let artifact = index.artifact("gcn-synth-cora-a2q").unwrap();
    let dataset = load_named(&dir, &artifact.dataset).unwrap();
    let Some(engine) = pjrt_engine() else { return };
    assert_eq!(engine.platform().unwrap(), "cpu");
    let exec = PjrtExecutor::new(engine, &artifact, Some(&dataset)).unwrap();

    let n_head = artifact.expected_head.len() / artifact.out_dim;
    let ids: Vec<u32> = (0..n_head as u32).collect();
    let outputs = exec.run_node_batch(&ids).unwrap();
    let flat: Vec<f32> = outputs.into_iter().flatten().collect();
    for (i, (g, w)) in flat.iter().zip(&artifact.expected_head).enumerate() {
        assert!(
            (g - w).abs() < 1e-3 + 1e-3 * w.abs(),
            "logit {i}: pjrt {g} vs python-recorded {w}"
        );
    }
}

#[test]
fn pjrt_matches_native_rust_forward() {
    let Some(dir) = artifacts() else { return };
    let index = ArtifactIndex::load(&dir).unwrap();
    let artifact = index.artifact("gcn-synth-cora-a2q").unwrap();
    let dataset = load_named(&dir, &artifact.dataset).unwrap();
    let Some(engine) = pjrt_engine() else { return };
    let exec = PjrtExecutor::new(engine, &artifact, Some(&dataset)).unwrap();

    let model = GnnModel::load(&index.dir, &artifact.name).unwrap();
    let Dataset::Node(ds) = &dataset else { panic!() };
    let ef = EdgeForm::from_csr(&ds.csr);
    let input = GraphInput::node_level(&ds.features, ds.num_features, &ef);
    let native = forward_fp(&model, &input);

    let ids: Vec<u32> = (0..64).collect();
    let pjrt_out = exec.run_node_batch(&ids).unwrap();
    for (v, row) in ids.iter().zip(&pjrt_out) {
        let nrow = native.row(*v as usize);
        for (a, b) in row.iter().zip(nrow) {
            assert!(
                (a - b).abs() < 2e-2 + 0.05 * b.abs(),
                "node {v}: pjrt {a} vs native {b}"
            );
        }
    }
}

#[test]
fn pallas_variant_matches_jnp_variant() {
    let Some(dir) = artifacts() else { return };
    let index = ArtifactIndex::load(&dir).unwrap();
    let (Ok(a_jnp), Ok(a_pl)) = (
        index.artifact("gcn-synth-cora-a2q"),
        index.artifact("gcn-synth-cora-a2q-pallas"),
    ) else {
        return;
    };
    let dataset = load_named(&dir, &a_jnp.dataset).unwrap();
    let Some(engine) = pjrt_engine() else { return };
    let e1 = PjrtExecutor::new(engine.clone(), &a_jnp, Some(&dataset)).unwrap();
    let e2 = PjrtExecutor::new(engine, &a_pl, Some(&dataset)).unwrap();
    let ids: Vec<u32> = (0..32).collect();
    let o1 = e1.run_node_batch(&ids).unwrap();
    let o2 = e2.run_node_batch(&ids).unwrap();
    for (r1, r2) in o1.iter().zip(&o2) {
        for (a, b) in r1.iter().zip(r2) {
            assert!((a - b).abs() < 1e-4 + 1e-4 * b.abs(), "pallas {b} vs jnp {a}");
        }
    }
}

#[test]
fn coordinator_serves_pjrt_model_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let index = ArtifactIndex::load(&dir).unwrap();
    let artifact = index.artifact("gcn-synth-cora-a2q").unwrap();
    let dataset = load_named(&dir, &artifact.dataset).unwrap();
    let Some(engine) = pjrt_engine() else { return };
    let exec = Arc::new(PjrtExecutor::new(engine, &artifact, Some(&dataset)).unwrap());

    let mut coord = Coordinator::new();
    coord.add_model(
        &artifact.name,
        exec,
        BatcherConfig {
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
    );
    let resp = coord
        .submit_blocking(&artifact.name, Payload::ClassifyNodes(vec![0, 5, 10]))
        .unwrap();
    assert_eq!(resp.predictions.len(), 3);
    assert!(resp.predictions.iter().all(|p| p.class < artifact.out_dim));
    let snap = coord.metrics();
    assert_eq!(snap.responses, 1);
    coord.shutdown();
}

#[test]
fn graph_level_artifact_serves_batches() {
    let Some(dir) = artifacts() else { return };
    let index = ArtifactIndex::load(&dir).unwrap();
    let Ok(artifact) = index.artifact("gin-synth-zinc-a2q") else {
        return;
    };
    let Dataset::Graphs(gs) = load_named(&dir, &artifact.dataset).unwrap() else {
        panic!()
    };
    let Some(engine) = pjrt_engine() else { return };
    let exec = PjrtExecutor::new(engine, &artifact, None).unwrap();
    let graphs: Vec<&a2q::graph::io::SmallGraph> = gs.graphs.iter().take(4).collect();
    let out = exec.run_graph_batch(&graphs).unwrap();
    assert_eq!(out.len(), 4);
    for o in &out {
        assert_eq!(o.len(), artifact.out_dim);
        assert!(o.iter().all(|v| v.is_finite()));
    }
}
