//! Property-style parity: `forward_int` (true integer arithmetic over
//! bit-packed codes) must track `forward_fp` (fake-quant emulation) within
//! quantization tolerance on random GCN/GIN models, and both paths must be
//! bitwise independent of the parallelism budget (threads ∈ {1, 4}) and of
//! the SIMD dispatch (`tensor::simd::parity_isas()` — scalar plus the
//! active ISA when one is available).
//!
//! Runs on the `util::prop` harness: `A2Q_PROP_SEED=<seed>` replays one
//! failing case exactly (the failure message prints the seed),
//! `A2Q_PROP_CASES=<n>` overrides every property's case count.

use a2q::gnn::{
    forward_fp_prepared, forward_fp_prepared_with_plan, forward_fp_with, forward_int_prepared,
    forward_int_prepared_with_plan, forward_int_with, GnnModel, GraphInput, LayerParams,
    PreparedModel, QuantMethod,
};
use a2q::graph::generate::preferential_attachment;
use a2q::graph::norm::EdgeForm;
use a2q::quant::mixed::NodeQuantParams;
use a2q::tensor::simd::{self, Isa};
use a2q::tensor::Matrix;
use a2q::util::json::Json;
use a2q::util::prop::{property, Gen};
use a2q::util::rng::Rng;
use a2q::util::threadpool::ParallelConfig;

fn random_matrix(g: &mut Gen, rows: usize, cols: usize) -> Matrix<f32> {
    Matrix::from_vec(rows, cols, g.vec_normal(rows * cols, 0.5)).unwrap()
}

fn node_quant(g: &mut Gen, n: usize, signed: bool) -> NodeQuantParams {
    let steps = g.vec_uniform(n, 0.02, 0.1);
    let bits: Vec<u8> = (0..n).map(|_| g.usize_range(2, 9) as u8).collect();
    NodeQuantParams::new(steps, bits, signed).unwrap()
}

#[allow(clippy::too_many_arguments)]
fn random_model(
    g: &mut Gen,
    arch: &str,
    n: usize,
    in_dim: usize,
    hidden: usize,
    out_dim: usize,
    n_layers: usize,
) -> GnnModel {
    let mut layers = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let d_in = if l == 0 { in_dim } else { hidden };
        let d_out = if l == n_layers - 1 { out_dim } else { hidden };
        // input-layer features are signed; deeper gcn/gin maps are
        // post-ReLU, hence unsigned — mirrors GnnModel::load
        let lay = match arch {
            "gcn" => LayerParams {
                w: Some(random_matrix(g, d_in, d_out)),
                b: g.vec_uniform(d_out, -0.1, 0.1),
                w_steps: g.vec_uniform(d_out, 0.02, 0.08),
                feat: Some(node_quant(g, n, l == 0)),
                ..Default::default()
            },
            "gin" => LayerParams {
                w: Some(random_matrix(g, d_in, hidden)),
                b: g.vec_uniform(hidden, -0.1, 0.1),
                w_steps: g.vec_uniform(hidden, 0.02, 0.08),
                w2: Some(random_matrix(g, hidden, d_out)),
                b2: g.vec_uniform(d_out, -0.1, 0.1),
                w2_steps: g.vec_uniform(d_out, 0.02, 0.08),
                eps: g.f32_range(0.0, 0.2),
                feat: Some(node_quant(g, n, l == 0)),
                feat2: Some(node_quant(g, n, false)),
                ..Default::default()
            },
            other => panic!("unexpected arch {other}"),
        };
        layers.push(lay);
    }
    GnnModel {
        name: format!("prop-{arch}"),
        arch: arch.to_string(),
        dataset: "synthetic".to_string(),
        method: QuantMethod::A2q,
        layers,
        head: None,
        dq_steps: Vec::new(),
        skip_input_quant: false,
        node_level: true,
        num_nodes: n,
        in_dim,
        out_dim,
        heads: 1,
        graph_capacity: 0,
        accuracy: 0.0,
        avg_bits: 4.0,
        expected_head: Vec::new(),
        manifest: Json::Null,
    }
}

#[test]
fn int_path_matches_fp_within_quant_tolerance_and_threads() {
    property("forward_int ≈ forward_fp, thread-invariant", 12, |g: &mut Gen| {
        let n = g.usize_range(24, 120);
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        let csr = preferential_attachment(&mut rng, n, 2);
        let ef = EdgeForm::from_csr(&csr);
        let in_dim = g.usize_range(2, 10);
        let hidden = g.usize_range(2, 12);
        let out_dim = g.usize_range(2, 6);
        let n_layers = g.usize_range(1, 4);
        let x = g.vec_normal(n * in_dim, 0.5);

        let serial = ParallelConfig::serial();
        // min_rows_per_task small enough that these graphs actually take
        // the parallel code path
        let parallel = ParallelConfig {
            threads: 4,
            min_rows_per_task: 8,
            ..ParallelConfig::serial()
        };

        for arch in ["gcn", "gin"] {
            let model = random_model(g, arch, n, in_dim, hidden, out_dim, n_layers);
            let input = GraphInput::node_level(&x, in_dim, &ef);

            let fp_s = forward_fp_with(&model, &input, &serial);
            let int_s = forward_int_with(&model, &input, &serial);
            assert_eq!(fp_s.shape(), (n, out_dim));
            assert!(fp_s.data.iter().all(|v| v.is_finite()), "{arch}: fp finite");

            // GCN's integer path runs the identical f32 op sequence
            // (aggregation over quantized features + fp matmul of quantized
            // weights), so it matches bitwise.  GIN's hidden map goes
            // through the true integer matmul: the (Σ c·cw)·s·s' grouping
            // differs from fake-quant only by f32 rounding, except that in
            // layers ≥ 2 a ~1e-5 input perturbation can flip a code at a
            // rounding boundary — each flip moves one output element by at
            // most step·|ŵ| ≈ 0.06.  Tolerate isolated flips, catch
            // systematic divergence via the mean.
            let diff = fp_s.max_abs_diff(&int_s);
            if arch == "gcn" {
                assert!(diff <= 1e-6, "{arch}: int path diverged by {diff}");
            } else {
                let mean_diff = fp_s
                    .data
                    .iter()
                    .zip(&int_s.data)
                    .map(|(a, b)| (a - b).abs() as f64)
                    .sum::<f64>()
                    / fp_s.data.len() as f64;
                assert!(diff <= 0.2, "{arch}: int path max diff {diff}");
                assert!(mean_diff <= 2e-3, "{arch}: int path mean diff {mean_diff}");
            }

            // the parallel paths are bitwise identical to serial
            let fp_p = forward_fp_with(&model, &input, &parallel);
            let int_p = forward_int_with(&model, &input, &parallel);
            assert_eq!(fp_s.data, fp_p.data, "{arch}: fp parallel != serial");
            assert_eq!(int_s.data, int_p.data, "{arch}: int parallel != serial");
        }
    });
}

#[test]
fn prepared_sessions_bitwise_match_unprepared_path() {
    // the tentpole guarantee: preparing once (quantized weights, integer
    // codes, NNS tables, cached AggregationPlan) and serving many requests
    // is bitwise identical to the per-call re-derive-everything shim
    property("prepared == unprepared, bitwise", 10, |g: &mut Gen| {
        let n = g.usize_range(24, 100);
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        let csr = preferential_attachment(&mut rng, n, 2);
        let ef = EdgeForm::from_csr(&csr);
        let in_dim = g.usize_range(2, 8);
        let hidden = g.usize_range(2, 10);
        let out_dim = g.usize_range(2, 5);
        let n_layers = g.usize_range(1, 4);
        let x = g.vec_normal(n * in_dim, 0.5);
        let cfg = ParallelConfig {
            threads: g.usize_range(1, 5),
            min_rows_per_task: 8,
            ..ParallelConfig::serial()
        };

        for arch in ["gcn", "gin"] {
            let model = random_model(g, arch, n, in_dim, hidden, out_dim, n_layers);
            let input = GraphInput::node_level(&x, in_dim, &ef);
            let prep = PreparedModel::prepare(model.clone()).expect("prepare");
            let plan = ef.plan();

            let fp_shim = forward_fp_with(&model, &input, &cfg);
            let fp_prep = forward_fp_prepared(&prep, &input, &cfg);
            let fp_planned = forward_fp_prepared_with_plan(&prep, &input, Some(&plan), &cfg);
            assert_eq!(fp_shim.data, fp_prep.data, "{arch}: fp prepared diverged");
            assert_eq!(fp_shim.data, fp_planned.data, "{arch}: fp cached-plan diverged");

            let int_shim = forward_int_with(&model, &input, &cfg);
            let int_prep = forward_int_prepared(&prep, &input, &cfg);
            let int_planned = forward_int_prepared_with_plan(&prep, &input, Some(&plan), &cfg);
            assert_eq!(int_shim.data, int_prep.data, "{arch}: int prepared diverged");
            assert_eq!(int_shim.data, int_planned.data, "{arch}: int cached-plan diverged");

            // session reuse is stable across repeated requests
            let again = forward_fp_prepared(&prep, &input, &cfg);
            assert_eq!(fp_prep.data, again.data, "{arch}: session reuse drifted");
        }
    });
}

#[test]
fn bucketed_int_kernel_bitwise_matches_scratch_reference() {
    // ISSUE 5: the bucketed per-bitwidth kernels (word-aligned per-width
    // slabs, permutation scatter, add/sub fast path for b <= 2) must be
    // bitwise identical to the pre-bucketing scratch-unpack kernel — the
    // path forward_int used to run — for threads ∈ {1, 4} crossed with
    // every parity ISA (scalar oracle plus the active SIMD dispatch), over
    // model-shaped mixed-width slabs (the same per-node (step, bits)
    // family the forwards quantize with).  The int *forward* is asserted
    // thread- and ISA-invariant alongside, so the end-to-end path inherits
    // the kernel guarantee.
    property("bucketed == scratch kernel, threads 1|4 × ISA", 12, |g: &mut Gen| {
        let n = g.usize_range(8, 150);
        let f = g.usize_range(1, 40);
        let cols = g.usize_range(1, 16);
        let signed = g.bool(0.5);
        let params = node_quant_full_range(g, n, signed);
        let x = g.vec_normal(n * f, 0.6);
        let (codes, _steps) = params.quantize_codes(&x, f);
        let packed =
            a2q::quant::pack::pack_rows(&codes, &params.steps, &params.bits, f, signed);
        let w = Matrix::from_vec(
            f,
            cols,
            (0..f * cols).map(|i| (i % 15) as i32 - 7).collect(),
        )
        .unwrap();
        // the oracle is pinned scalar so it never depends on the dispatch
        // under test
        let scalar = ParallelConfig::serial().with_simd(Isa::Scalar);
        let want = packed.matmul_i32_scratch(&w, &scalar);
        for isa in simd::parity_isas() {
            for threads in [1usize, 4] {
                let cfg = ParallelConfig {
                    threads,
                    min_rows_per_task: 4,
                    simd: isa,
                };
                assert_eq!(
                    packed.matmul_i32(&w, &cfg).data,
                    want.data,
                    "bucketed diverged from scratch at t={threads} isa={}",
                    isa.name()
                );
                assert_eq!(
                    packed.matmul_i32_scratch(&w, &cfg).data,
                    want.data,
                    "scratch not thread/ISA-invariant at t={threads} isa={}",
                    isa.name()
                );
            }
        }

        // forward-level anchor: the int forward (now running the bucketed
        // kernels) stays bitwise invariant across threads × ISA
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        let csr = preferential_attachment(&mut rng, n, 2);
        let ef = EdgeForm::from_csr(&csr);
        let in_dim = g.usize_range(2, 6);
        let model = random_model(g, "gin", n, in_dim, g.usize_range(2, 8), cols.max(2), 2);
        let xin = g.vec_normal(n * in_dim, 0.5);
        let input = GraphInput::node_level(&xin, in_dim, &ef);
        let int_ref = forward_int_with(&model, &input, &scalar);
        for isa in simd::parity_isas() {
            for threads in [1usize, 4] {
                let cfg = ParallelConfig {
                    threads,
                    min_rows_per_task: 4,
                    simd: isa,
                };
                assert_eq!(
                    int_ref.data,
                    forward_int_with(&model, &input, &cfg).data,
                    "int forward not invariant at t={threads} isa={}",
                    isa.name()
                );
            }
        }
    });
}

/// Per-node params over the *full* 1..=8 width range (the forwards' helper
/// starts at 2; the kernel parity test must cover the 1-bit bucket too).
fn node_quant_full_range(g: &mut Gen, n: usize, signed: bool) -> NodeQuantParams {
    let steps = g.vec_uniform(n, 0.02, 0.1);
    let bits: Vec<u8> = (0..n).map(|_| g.usize_range(1, 9) as u8).collect();
    NodeQuantParams::new(steps, bits, signed).unwrap()
}

#[test]
fn zero_step_params_keep_int_and_fp_paths_consistent() {
    // degenerate learned steps (0.0 / negative) are clamped once at
    // NodeQuantParams construction, so the integer path's recorded rescale
    // step always matches the step the codes were computed with — no more
    // silently zeroed rows on the int side only
    property("zero-step int ≈ fp", 10, |g: &mut Gen| {
        let n = g.usize_range(24, 80);
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        let csr = preferential_attachment(&mut rng, n, 2);
        let ef = EdgeForm::from_csr(&csr);
        let in_dim = g.usize_range(2, 6);
        let hidden = g.usize_range(2, 8);
        let out_dim = g.usize_range(2, 4);
        let x = g.vec_normal(n * in_dim, 0.5);
        // GIN exercises the true integer matmul (the path that rescaled by
        // the raw recorded step); poison its hidden-map params with zeros
        let mut model = random_model(g, "gin", n, in_dim, hidden, out_dim, 2);
        for lay in model.layers.iter_mut() {
            let p = lay.feat2.take().unwrap();
            let mut steps = p.steps.clone();
            for (i, s) in steps.iter_mut().enumerate() {
                if i % 3 == 0 {
                    *s = 0.0;
                }
            }
            lay.feat2 = Some(NodeQuantParams::new(steps, p.bits.clone(), p.signed).unwrap());
        }
        let cfg = ParallelConfig::serial();
        let input = GraphInput::node_level(&x, in_dim, &ef);
        let fp = forward_fp_with(&model, &input, &cfg);
        let int = forward_int_with(&model, &input, &cfg);
        assert!(fp.data.iter().all(|v| v.is_finite()), "fp not finite");
        assert!(int.data.iter().all(|v| v.is_finite()), "int not finite");
        // a zero step quantizes to (±levels · MIN_STEP) ≈ 0 on *both*
        // paths; systematic divergence would show up in the mean
        let mean_diff = fp
            .data
            .iter()
            .zip(&int.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / fp.data.len() as f64;
        assert!(mean_diff <= 2e-3, "zero-step int path diverged: {mean_diff}");
    });
}

#[test]
fn fp32_method_ignores_quant_params() {
    // sanity anchor for the harness above: with method = Fp32 the int path
    // delegates to fp and both are exactly equal
    let mut g = Gen::new(7);
    let n = 40;
    let mut rng = Rng::new(3);
    let csr = preferential_attachment(&mut rng, n, 2);
    let ef = EdgeForm::from_csr(&csr);
    let x = g.vec_normal(n * 4, 0.5);
    let mut model = random_model(&mut g, "gcn", n, 4, 8, 3, 2);
    model.method = QuantMethod::Fp32;
    let input = GraphInput::node_level(&x, 4, &ef);
    let cfg = ParallelConfig::with_threads(4);
    let fp = forward_fp_with(&model, &input, &cfg);
    let int = forward_int_with(&model, &input, &cfg);
    assert_eq!(fp.data, int.data);
}
