//! Sharded-resident parity: the shard-parallel execution path (degree-aware
//! partition, per-shard plans, halo exchange between layers, per-shard
//! logits blocks) must be **bitwise identical** to the single-shard
//! prepared path — for fp AND int logits, S ∈ {1, 2, 4}, thread counts
//! crossed 1 ↔ 4, and across random [`GraphDelta`] sequences applied to a
//! sharded `NativeExecutor` versus a fresh unsharded session over the
//! extended graph.  Plus adversarial delta edge cases and a mixed
//! inference+update soak against a sharded executor behind the
//! coordinator (metrics conservation, exactly-once epochs, no stale or
//! torn reads).
//!
//! Runs on the `util::prop` harness: `A2Q_PROP_SEED=<seed>` replays one
//! failing case exactly (the failure message prints the seed),
//! `A2Q_PROP_CASES=<n>` overrides every property's case count.

use std::collections::BTreeSet;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use a2q::coordinator::{BatchExecutor, BatcherConfig, Coordinator, NativeExecutor, Payload};
use a2q::gnn::{
    forward_fp_prepared, forward_fp_sharded, forward_fp_with, forward_int_prepared,
    forward_int_sharded, forward_int_with, GnnModel, GraphInput, LayerParams, PreparedModel,
    QuantMethod,
};
use a2q::graph::delta::GraphDelta;
use a2q::graph::generate::preferential_attachment;
use a2q::graph::io::{Dataset, NodeData};
use a2q::graph::norm::EdgeForm;
use a2q::graph::shard::ShardedGraph;
use a2q::graph::Csr;
use a2q::quant::mixed::NodeQuantParams;
use a2q::tensor::simd::{self, Isa};
use a2q::tensor::Matrix;
use a2q::util::json::Json;
use a2q::util::prop::{property, Gen};
use a2q::util::rng::Rng;
use a2q::util::threadpool::ParallelConfig;

fn random_matrix(g: &mut Gen, rows: usize, cols: usize) -> Matrix<f32> {
    Matrix::from_vec(rows, cols, g.vec_normal(rows * cols, 0.5)).unwrap()
}

fn node_quant(g: &mut Gen, n: usize, signed: bool) -> NodeQuantParams {
    let steps = g.vec_uniform(n, 0.02, 0.1);
    let bits: Vec<u8> = (0..n).map(|_| g.usize_range(2, 9) as u8).collect();
    NodeQuantParams::new(steps, bits, signed).unwrap()
}

#[allow(clippy::too_many_arguments)]
fn random_model(
    g: &mut Gen,
    arch: &str,
    n: usize,
    in_dim: usize,
    hidden: usize,
    out_dim: usize,
    n_layers: usize,
) -> GnnModel {
    let mut layers = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let d_in = if l == 0 { in_dim } else { hidden };
        let d_out = if l == n_layers - 1 { out_dim } else { hidden };
        let lay = match arch {
            "gcn" => LayerParams {
                w: Some(random_matrix(g, d_in, d_out)),
                b: g.vec_uniform(d_out, -0.1, 0.1),
                w_steps: g.vec_uniform(d_out, 0.02, 0.08),
                feat: Some(node_quant(g, n, l == 0)),
                ..Default::default()
            },
            "gin" => LayerParams {
                w: Some(random_matrix(g, d_in, hidden)),
                b: g.vec_uniform(hidden, -0.1, 0.1),
                w_steps: g.vec_uniform(hidden, 0.02, 0.08),
                w2: Some(random_matrix(g, hidden, d_out)),
                b2: g.vec_uniform(d_out, -0.1, 0.1),
                w2_steps: g.vec_uniform(d_out, 0.02, 0.08),
                eps: g.f32_range(0.0, 0.2),
                feat: Some(node_quant(g, n, l == 0)),
                feat2: Some(node_quant(g, n, false)),
                ..Default::default()
            },
            other => panic!("unexpected arch {other}"),
        };
        layers.push(lay);
    }
    GnnModel {
        name: format!("shard-{arch}"),
        arch: arch.to_string(),
        dataset: "synthetic".to_string(),
        method: QuantMethod::A2q,
        layers,
        head: None,
        dq_steps: Vec::new(),
        skip_input_quant: false,
        node_level: true,
        num_nodes: n,
        in_dim,
        out_dim,
        heads: 1,
        graph_capacity: 0,
        accuracy: 0.0,
        avg_bits: 4.0,
        expected_head: Vec::new(),
        manifest: Json::Null,
    }
}

fn node_dataset(csr: Csr, features: Vec<f32>, feat_dim: usize) -> Dataset {
    let n = csr.num_nodes();
    Dataset::Node(NodeData {
        name: "synthetic".into(),
        csr,
        num_features: feat_dim,
        num_classes: 2,
        features,
        labels: vec![0; n],
        train_mask: vec![false; n],
        val_mask: vec![false; n],
        test_mask: vec![false; n],
    })
}

fn random_delta(
    g: &mut Gen,
    n_cur: usize,
    in_dim: usize,
    edge_set: &BTreeSet<(u32, u32)>,
) -> GraphDelta {
    let add_nodes = g.usize_range(0, 3);
    let n_new = n_cur + add_nodes;
    let existing: Vec<(u32, u32)> = edge_set.iter().copied().collect();
    let add_edges: Vec<(u32, u32)> = (0..g.usize_range(0, 10))
        .map(|_| (g.usize_range(0, n_new) as u32, g.usize_range(0, n_new) as u32))
        .collect();
    let mut remove_edges: Vec<(u32, u32)> = if existing.is_empty() {
        Vec::new()
    } else {
        (0..g.usize_range(0, 5))
            .map(|_| existing[g.usize_range(0, existing.len())])
            .collect()
    };
    remove_edges.push((g.usize_range(0, n_new) as u32, g.usize_range(0, n_new) as u32));
    GraphDelta {
        add_nodes,
        new_features: g.vec_normal(add_nodes * in_dim, 0.5),
        add_edges,
        remove_edges,
    }
}

/// Clone the original model with the executor's post-delta quantization
/// parameters and node count (NNS-assigned entries for appended nodes are
/// resident state a rebuild needs).
fn extended_model(original: &GnnModel, exec: &NativeExecutor, n_cur: usize) -> GnnModel {
    let mut m = original.clone();
    for (lay, (f, f2)) in m.layers.iter_mut().zip(exec.resident_quant_params()) {
        lay.feat = f;
        lay.feat2 = f2;
    }
    m.num_nodes = n_cur;
    m
}

/// Tentpole guarantee, forward level: fp and int sharded logits are
/// bitwise equal to the single-shard prepared path for S ∈ {1, 2, 4},
/// with the thread counts crossed 1 ↔ 4 so every compare simultaneously
/// checks shard-parallel vs single-shard AND thread-count invariance.
#[test]
fn sharded_forward_bitwise_vs_prepared_path() {
    property("sharded == prepared (fp/int, S∈{1,2,4}, threads 1↔4)", 6, |g: &mut Gen| {
        let n = g.usize_range(24, 80);
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        let csr = preferential_attachment(&mut rng, n, 2);
        let ef = EdgeForm::from_csr(&csr);
        let in_dim = g.usize_range(2, 6);
        let hidden = g.usize_range(2, 8);
        let out_dim = g.usize_range(2, 5);
        let n_layers = g.usize_range(1, 4);
        let x = g.vec_normal(n * in_dim, 0.5);

        let one = ParallelConfig::serial();
        let four = ParallelConfig {
            threads: 4,
            min_rows_per_task: 8,
            ..ParallelConfig::serial()
        };

        for arch in ["gcn", "gin"] {
            let model = random_model(g, arch, n, in_dim, hidden, out_dim, n_layers);
            let prep = PreparedModel::prepare(model).expect("prepare");
            let input = GraphInput::node_level(&x, in_dim, &ef);
            // references at one thread count, sharded runs at the other
            let want_fp = forward_fp_prepared(&prep, &input, &one);
            let want_int = forward_int_prepared(&prep, &input, &four);
            for s in [1usize, 2, 4] {
                let sg = ShardedGraph::build(&csr, &ef, s).expect("shard build");
                assert_eq!(sg.num_shards(), s);
                let got_fp = forward_fp_sharded(&prep, &x, &sg, &four);
                assert_eq!(want_fp.data, got_fp.data, "{arch} S={s}: fp diverged");
                let got_int = forward_int_sharded(&prep, &x, &sg, &one);
                assert_eq!(want_int.data, got_int.data, "{arch} S={s}: int diverged");
                // S = 1 has no halo; S > 1 on a connected power-law graph
                // must exchange something
                let stats = sg.halo_stats();
                if s == 1 {
                    assert_eq!(stats.halo_edges, 0);
                } else {
                    assert!(stats.halo_edges > 0, "{arch} S={s}: no halo on a connected graph");
                }
            }
        }
    });
}

/// ISSUE 5, shard level: every shard's owned slab (`pack_rows_subset` over
/// the shard's global ids, mixed per-node bitwidths) must run the bucketed
/// kernels bitwise identically to the scratch-unpack reference, for
/// S ∈ {1, 2, 4} and threads ∈ {1, 4}.  Combined with
/// `sharded_forward_bitwise_vs_prepared_path` (which now runs the bucketed
/// kernels end-to-end on both sides), this pins the sharded integer path
/// to the pre-bucketing behaviour.
#[test]
fn shard_slabs_bucketed_kernel_matches_scratch_reference() {
    property("shard slab bucketed == scratch (S∈{1,2,4})", 8, |g: &mut Gen| {
        let n = g.usize_range(16, 90);
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        let csr = preferential_attachment(&mut rng, n, 2);
        let ef = EdgeForm::from_csr(&csr);
        let f = g.usize_range(1, 24);
        let cols = g.usize_range(1, 12);
        let signed = g.bool(0.5);
        // full 1..=8 width range (node_quant starts at 2; the kernel
        // parity must cover the 1-bit bucket)
        let steps = g.vec_uniform(n, 0.02, 0.1);
        let bits: Vec<u8> = (0..n).map(|_| g.usize_range(1, 9) as u8).collect();
        let params = NodeQuantParams::new(steps, bits, signed).unwrap();
        let x = g.vec_normal(n * f, 0.6);
        let (codes, _) = params.quantize_codes(&x, f);
        let w = Matrix::from_vec(
            f,
            cols,
            (0..f * cols).map(|i| (i % 13) as i32 - 6).collect(),
        )
        .unwrap();
        let serial = ParallelConfig::serial();
        for s in [1usize, 2, 4] {
            let sg = ShardedGraph::build(&csr, &ef, s).expect("shard build");
            for sh in &sg.shards {
                let sub_codes: Vec<i32> = sh
                    .owned
                    .iter()
                    .flat_map(|&v| codes[v as usize * f..(v as usize + 1) * f].to_vec())
                    .collect();
                let slab = a2q::quant::pack::pack_rows_subset(
                    &sub_codes,
                    &params.steps,
                    &params.bits,
                    &sh.owned,
                    f,
                    signed,
                );
                // scalar-pinned oracle, compared across threads × ISA
                let want = slab.matmul_i32_scratch(&w, &serial.with_simd(Isa::Scalar));
                for isa in simd::parity_isas() {
                    for threads in [1usize, 4] {
                        let cfg = ParallelConfig {
                            threads,
                            min_rows_per_task: 2,
                            simd: isa,
                        };
                        assert_eq!(
                            slab.matmul_i32(&w, &cfg).data,
                            want.data,
                            "S={s} t={threads} isa={}: shard slab bucketed != scratch",
                            isa.name()
                        );
                    }
                }
                // the slab's recorded rescale steps are the gathered
                // clamped per-node steps, in owned order
                for (li, &gid) in sh.owned.iter().enumerate() {
                    assert_eq!(slab.steps()[li], params.steps[gid as usize]);
                }
            }
        }
    });
}

/// Tentpole guarantee, serving level: random delta sequences applied to
/// **sharded** executors match a fresh unsharded session over the
/// extended graph bitwise, fp and int, thread counts crossed 1 ↔ 4.
#[test]
fn sharded_executor_delta_sequences_match_fresh_unsharded() {
    property("sharded deltas == fresh unsharded rebuild", 4, |g: &mut Gen| {
        let n0 = g.usize_range(16, 40);
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        let csr0 = preferential_attachment(&mut rng, n0, 2);
        let in_dim = g.usize_range(2, 5);
        let hidden = g.usize_range(2, 6);
        let out_dim = g.usize_range(2, 4);
        let n_layers = g.usize_range(1, 3);
        let features0 = g.vec_normal(n0 * in_dim, 0.5);

        let one = ParallelConfig::serial();
        let four = ParallelConfig {
            threads: 4,
            min_rows_per_task: 8,
            ..ParallelConfig::serial()
        };

        for arch in ["gcn", "gin"] {
            let s = *g.choose(&[2usize, 4]);
            let model = random_model(g, arch, n0, in_dim, hidden, out_dim, n_layers);
            let ds = node_dataset(csr0.clone(), features0.clone(), in_dim);
            // fp sharded executor at 4 threads vs 1-thread rebuilds; int
            // sharded executor at 1 thread vs 4-thread rebuilds
            let exec_fp = NativeExecutor::new(model.clone(), Some(&ds))
                .unwrap()
                .with_parallelism(four)
                .with_shards(s)
                .unwrap();
            let exec_int = NativeExecutor::new(model.clone(), Some(&ds))
                .unwrap()
                .with_int_path(true)
                .with_parallelism(one)
                .with_shards(s)
                .unwrap();
            // warm the fp session through the per-shard blocks; leave the
            // int session cold (its first delta warms the acts itself)
            exec_fp.run_node_batch(&[0]).unwrap();

            let mut edge_set: BTreeSet<(u32, u32)> = csr0.edge_list().into_iter().collect();
            let mut features = features0.clone();
            let mut n_cur = n0;

            for step in 0..2 {
                let delta = random_delta(g, n_cur, in_dim, &edge_set);
                let rep_fp = exec_fp.apply_delta(&delta).unwrap();
                exec_int.apply_delta(&delta).unwrap();
                n_cur += delta.add_nodes;
                features.extend_from_slice(&delta.new_features);
                for &e in &delta.add_edges {
                    edge_set.insert(e);
                }
                for &e in &delta.remove_edges {
                    edge_set.remove(&e);
                }
                assert_eq!(rep_fp.num_nodes, n_cur);

                let full: Vec<(u32, u32)> = edge_set.iter().copied().collect();
                let rebuilt = Csr::from_edges(n_cur, &full).unwrap();
                let ef = EdgeForm::from_csr(&rebuilt);
                let input = GraphInput::node_level(&features, in_dim, &ef);
                let all: Vec<u32> = (0..n_cur as u32).collect();

                let fp_model = extended_model(&model, &exec_fp, n_cur);
                let want_fp = forward_fp_with(&fp_model, &input, &one);
                for (v, row) in exec_fp.run_node_batch(&all).unwrap().iter().enumerate() {
                    assert_eq!(
                        row.as_slice(),
                        want_fp.row(v),
                        "{arch} S={s} step {step}: fp row {v} diverged"
                    );
                }

                let int_model = extended_model(&model, &exec_int, n_cur);
                let want_int = forward_int_with(&int_model, &input, &four);
                for (v, row) in exec_int.run_node_batch(&all).unwrap().iter().enumerate() {
                    assert_eq!(
                        row.as_slice(),
                        want_int.row(v),
                        "{arch} S={s} step {step}: int row {v} diverged"
                    );
                }
            }
        }
    });
}

/// Adversarial delta edge cases on a sharded resident, each checked
/// incremental-vs-rebuild bitwise: add+remove of the same edge in one
/// delta, a self-loop on an appended node, edges between two nodes
/// appended in the same delta, and an empty delta.
#[test]
fn adversarial_deltas_on_sharded_residents_match_rebuild() {
    property("adversarial deltas == rebuild (sharded)", 4, |g: &mut Gen| {
        let n0 = g.usize_range(10, 26);
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        let csr0 = preferential_attachment(&mut rng, n0, 2);
        let in_dim = g.usize_range(2, 4);
        let features0 = g.vec_normal(n0 * in_dim, 0.5);
        let model = random_model(g, "gin", n0, in_dim, 4, 3, 2);
        let ds = node_dataset(csr0.clone(), features0.clone(), in_dim);
        let s = *g.choose(&[2usize, 3]);
        let exec = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial())
            .with_shards(s)
            .unwrap();
        exec.run_node_batch(&[0]).unwrap();

        let mut edge_set: BTreeSet<(u32, u32)> = csr0.edge_list().into_iter().collect();
        let mut features = features0.clone();
        let mut n_cur = n0;

        let existing = *g.choose(&edge_set.iter().copied().collect::<Vec<_>>());
        let scenarios: Vec<(&str, GraphDelta)> = vec![
            (
                "same edge added and removed in one delta (ends removed)",
                GraphDelta {
                    add_edges: vec![existing],
                    remove_edges: vec![existing],
                    ..Default::default()
                },
            ),
            (
                "self-loop on an appended node",
                GraphDelta {
                    add_nodes: 1,
                    new_features: g.vec_normal(in_dim, 0.5),
                    add_edges: vec![
                        (n_cur as u32, n_cur as u32),
                        (n_cur as u32, 0),
                        (0, n_cur as u32),
                    ],
                    ..Default::default()
                },
            ),
            (
                "edges between two nodes appended in the same delta",
                GraphDelta {
                    add_nodes: 2,
                    new_features: g.vec_normal(2 * in_dim, 0.5),
                    add_edges: vec![
                        ((n_cur + 1) as u32, (n_cur + 2) as u32),
                        ((n_cur + 2) as u32, (n_cur + 1) as u32),
                        (0, (n_cur + 1) as u32),
                    ],
                    ..Default::default()
                },
            ),
            ("empty delta on a sharded resident", GraphDelta::default()),
        ];

        let mut last_rows: Option<Vec<Vec<f32>>> = None;
        for (what, delta) in scenarios {
            let before_epoch = exec.epoch();
            let report = exec.apply_delta(&delta).unwrap();
            assert_eq!(report.epoch, before_epoch + 1, "{what}: epoch not exactly-once");
            // mirror set-wise
            n_cur += delta.add_nodes;
            features.extend_from_slice(&delta.new_features);
            for &e in &delta.add_edges {
                edge_set.insert(e);
            }
            for &e in &delta.remove_edges {
                edge_set.remove(&e);
            }
            let full: Vec<(u32, u32)> = edge_set.iter().copied().collect();
            let rebuilt = Csr::from_edges(n_cur, &full).unwrap();
            let all: Vec<u32> = (0..n_cur as u32).collect();
            let got = exec.run_node_batch(&all).unwrap();

            let ext = extended_model(&model, &exec, n_cur);
            let fresh = NativeExecutor::new(
                ext,
                Some(&node_dataset(rebuilt, features.clone(), in_dim)),
            )
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
            let want = fresh.run_node_batch(&all).unwrap();
            assert_eq!(got, want, "{what}: sharded incremental diverged from rebuild");
            if delta.is_empty() {
                // the empty delta must carry every row over bit-for-bit
                assert_eq!(
                    Some(&got),
                    last_rows.as_ref(),
                    "{what}: rows moved across an empty delta"
                );
                assert_eq!(report.recomputed_rows, 0);
                assert_eq!(report.shards_touched, 0);
            }
            last_rows = Some(got);
        }
    });
}

/// Soak: mixed inference + update clients against a **sharded**
/// `NativeExecutor` behind the coordinator.  Asserts metric conservation
/// (every submit counted exactly once as admitted or rejected, every
/// admitted request answered exactly once, updates counted exactly once),
/// exactly-once epoch bumps across shards (final epoch == successful
/// updates), and that every served probe row equals a committed state —
/// never a stale mix or a torn read.
#[test]
fn soak_sharded_executor_under_mixed_load() {
    let n = 48;
    let in_dim = 2;
    let mut g = Gen::new(0xa2a2_5042);
    let mut rng = Rng::new(9);
    let csr = preferential_attachment(&mut rng, n, 2);
    let features = g.vec_normal(n * in_dim, 0.5);
    let model = random_model(&mut g, "gcn", n, in_dim, 4, 3, 1);
    let ds = node_dataset(csr.clone(), features.clone(), in_dim);

    // an edge not present in the base graph, toggled by the updater
    let probe_src = (1..n as u32)
        .find(|src| !csr.in_neighbors(0).contains(src))
        .expect("node 0 has a non-neighbour");
    let toggled: Vec<(u32, u32)> = {
        let mut e = csr.edge_list();
        e.push((probe_src, 0));
        e
    };
    let csr_b = Csr::from_edges(n, &toggled).unwrap();

    // the two committed states of the probe row (node 0)
    let serial = ParallelConfig::serial();
    let row_for = |csr: &Csr| -> Vec<f32> {
        let ef = EdgeForm::from_csr(csr);
        let input = GraphInput::node_level(&features, in_dim, &ef);
        forward_fp_with(&model, &input, &serial).row(0).to_vec()
    };
    let a_row = row_for(&csr);
    let b_row = row_for(&csr_b);
    assert_ne!(a_row, b_row, "the toggled edge must move the probe row");

    let exec = Arc::new(
        NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(serial)
            .with_shards(4)
            .unwrap(),
    );
    let mut c = Coordinator::new();
    c.add_model(
        "sharded",
        exec.clone() as Arc<dyn BatchExecutor>,
        BatcherConfig {
            node_budget: 64,
            graph_slots: 8,
            max_wait: Duration::from_micros(200),
            queue_cap: 4,
            ..BatcherConfig::default()
        },
    );
    let c = Arc::new(c);

    // the mutating client: toggles the probe edge, flipping only on success
    let updater = {
        let c = Arc::clone(&c);
        thread::spawn(move || {
            let (mut ok, mut rejected) = (0u64, 0u64);
            let mut present = false;
            for _ in 0..24 {
                let delta = if present {
                    GraphDelta {
                        remove_edges: vec![(probe_src, 0)],
                        ..Default::default()
                    }
                } else {
                    GraphDelta {
                        add_edges: vec![(probe_src, 0)],
                        ..Default::default()
                    }
                };
                match c.submit("sharded", Payload::UpdateGraph(delta)) {
                    Ok(rx) => {
                        let resp = rx.recv().expect("runner alive").expect("update ok");
                        assert!(resp.predictions.is_empty());
                        present = !present;
                        ok += 1;
                    }
                    Err(_) => rejected += 1,
                }
            }
            (ok, rejected, present)
        })
    };
    let mut classifiers = Vec::new();
    for _ in 0..3 {
        let c = Arc::clone(&c);
        let a_row = a_row.clone();
        let b_row = b_row.clone();
        classifiers.push(thread::spawn(move || {
            let (mut ok, mut rejected, mut torn) = (0u64, 0u64, 0u64);
            for _ in 0..40 {
                match c.submit("sharded", Payload::ClassifyNodes(vec![0])) {
                    Ok(rx) => {
                        let resp = rx.recv().expect("runner alive").expect("classify ok");
                        ok += 1;
                        let row = &resp.predictions[0].output;
                        if row != &a_row && row != &b_row {
                            torn += 1;
                        }
                    }
                    Err(_) => rejected += 1,
                }
            }
            (ok, rejected, torn)
        }));
    }

    let (update_ok, update_rej, mut present) = updater.join().unwrap();
    let (mut admitted, mut rejected, mut torn) = (update_ok, update_rej, 0u64);
    for j in classifiers {
        let (ok, rej, t) = j.join().unwrap();
        admitted += ok;
        rejected += rej;
        torn += t;
    }
    assert_eq!(torn, 0, "served probe rows must equal a committed state");
    assert_eq!(admitted + rejected, 24 + 3 * 40, "every submit counted once");
    let snap = c.metrics();
    assert_eq!(snap.requests, admitted, "admitted counted exactly once");
    assert_eq!(snap.rejected, rejected, "rejected counted exactly once");
    assert_eq!(snap.responses, admitted, "every admitted request answered once");
    assert_eq!(snap.errors, 0, "no executor errors under the soak");
    assert_eq!(snap.updates, update_ok, "updates counted exactly once");
    assert_eq!(
        exec.epoch(),
        update_ok,
        "epoch bumps exactly once per update across shards"
    );

    // sequential tail: a classify admitted after an update's reply must
    // observe exactly the post-update state (never stale)
    for _ in 0..4 {
        let delta = if present {
            GraphDelta {
                remove_edges: vec![(probe_src, 0)],
                ..Default::default()
            }
        } else {
            GraphDelta {
                add_edges: vec![(probe_src, 0)],
                ..Default::default()
            }
        };
        c.submit_blocking("sharded", Payload::UpdateGraph(delta)).unwrap();
        present = !present;
        let resp = c
            .submit_blocking("sharded", Payload::ClassifyNodes(vec![0]))
            .unwrap();
        let want = if present { &b_row } else { &a_row };
        assert_eq!(&resp.predictions[0].output, want, "stale probe row");
    }
    assert_eq!(exec.epoch(), update_ok + 4);
    assert!(
        c.metrics().shard_rebuilds > 0,
        "sharded updates must report shard rebuilds"
    );

    // final full parity against a fresh unsharded session over the end state
    let final_csr = if present { csr_b } else { csr };
    let fresh = NativeExecutor::new(model, Some(&node_dataset(final_csr, features, in_dim)))
        .unwrap()
        .with_parallelism(ParallelConfig::serial());
    let all: Vec<u32> = (0..n as u32).collect();
    assert_eq!(
        exec.run_node_batch(&all).unwrap(),
        fresh.run_node_batch(&all).unwrap(),
        "end-state sharded logits diverged from a fresh unsharded session"
    );

    Arc::try_unwrap(c).ok().map(|c| c.shutdown());
}
