//! Tier-2: durable resident state — restart parity and torn-tail recovery.
//!
//! The contract under test (`runtime::persist` + `NativeExecutor`):
//!
//! 1. **Restart parity** — snapshot + WAL-tail recovery reproduces the
//!    served logits **bit-for-bit** against a continuously-running
//!    executor, unsharded and sharded (S ∈ {2, 4}).  This is
//!    `delta_parity`/`shard_parity` extended across a process boundary:
//!    the "restarted process" is a fresh executor built from the same
//!    artifact plus the state directory.
//! 2. **Torn-tail crash injection** — a WAL cut at *every* byte offset of
//!    its final record (and at the exact record boundary) recovers the
//!    longest valid prefix: never a panic, never a half-applied record,
//!    and the dropped byte count is reported, not swallowed.
//!
//! The random half of (2) runs under `util::prop`, so a failure prints an
//! `A2Q_PROP_SEED` one-liner that replays the exact corruption.

use std::path::PathBuf;

use a2q::coordinator::{synthetic_node_session, BatchExecutor, NativeExecutor};
use a2q::graph::delta::GraphDelta;
use a2q::runtime::{PersistConfig, Persistence};
use a2q::util::prop::{property, Gen};
use a2q::util::threadpool::ParallelConfig;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("a2q_recov_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The delta workload every parity test replays: edge growth, two node
/// appends (NNS-assigned params), an empty barrier, and an edge removal.
fn workload() -> Vec<GraphDelta> {
    vec![
        GraphDelta {
            add_edges: vec![(5, 0), (0, 5), (7, 3), (3, 7)],
            ..Default::default()
        },
        GraphDelta {
            add_nodes: 1,
            new_features: vec![0.2, -0.1, 0.4, -0.3],
            add_edges: vec![(32, 0), (0, 32), (32, 9), (9, 32)],
            ..Default::default()
        },
        GraphDelta::default(),
        GraphDelta {
            add_nodes: 1,
            new_features: vec![-0.25, 0.15, -0.05, 0.35],
            add_edges: vec![(33, 32), (32, 33), (33, 1), (1, 33)],
            ..Default::default()
        },
        GraphDelta {
            remove_edges: vec![(5, 0), (7, 3)],
            ..Default::default()
        },
    ]
}

fn build(shards: Option<usize>) -> NativeExecutor {
    let (model, ds) = synthetic_node_session(32, 9).unwrap();
    let exec = NativeExecutor::new(model, Some(&ds))
        .unwrap()
        .with_parallelism(ParallelConfig::serial());
    match shards {
        Some(s) => exec.with_shards(s).unwrap(),
        None => exec,
    }
}

/// Restart parity across a "process boundary": unsharded and S ∈ {2, 4}.
/// `snapshot_every = 3` forces a mid-workload rotation, so recovery
/// exercises snapshot restore *and* WAL-tail replay, not just one of them.
#[test]
fn restart_reproduces_continuous_logits_bitwise_for_all_shard_layouts() {
    for shards in [None, Some(2), Some(4)] {
        let tag = format!("restart_s{}", shards.unwrap_or(1));
        let dir = tmp_dir(&tag);
        let mut cfg = PersistConfig::new(&dir);
        cfg.snapshot_every = 3;

        let (exec, restore) = build(shards).with_persistence(cfg.clone()).unwrap();
        assert!(!restore.restored_snapshot, "{tag}: fresh dir");
        for d in &workload() {
            exec.apply_delta(d).unwrap();
        }
        let all: Vec<u32> = (0..34).collect();
        let want = exec.run_node_batch(&all).unwrap();
        let want_epoch = exec.epoch();
        let want_params = exec.resident_quant_params();
        drop(exec);

        // "restarted process": a fresh executor over the same artifact +
        // state dir.  Sharded sessions re-partition from scratch — shard
        // parity makes the layout difference invisible in the logits.
        let (back, restore) = build(shards).with_persistence(cfg).unwrap();
        assert!(
            restore.restored_snapshot,
            "{tag}: snapshot_every=3 must have rotated"
        );
        assert_eq!(restore.epoch, want_epoch, "{tag}: epoch survives restart");
        assert_eq!(restore.num_nodes, 34, "{tag}");
        assert_eq!(
            back.run_node_batch(&all).unwrap(),
            want,
            "{tag}: restart parity broke"
        );
        assert_eq!(back.epoch(), want_epoch, "{tag}");
        let got_params = back.resident_quant_params();
        assert_eq!(want_params.len(), got_params.len(), "{tag}");
        for (l, ((wf, _), (gf, _))) in want_params.iter().zip(&got_params).enumerate() {
            let (wf, gf) = (wf.as_ref().unwrap(), gf.as_ref().unwrap());
            assert_eq!(wf.steps, gf.steps, "{tag}: layer {l} steps");
            assert_eq!(wf.bits, gf.bits, "{tag}: layer {l} bits");
        }

        // recovered sessions keep evolving: one more delta on both sides
        // of the boundary stays in lockstep
        let extra = GraphDelta {
            add_edges: vec![(33, 0), (0, 33)],
            ..Default::default()
        };
        let report = back.apply_delta(&extra).unwrap();
        assert_eq!(report.epoch, want_epoch + 1, "{tag}: replay keeps bumping");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Append `deltas` to a fresh WAL-only state dir (snapshots disabled) and
/// return the log bytes plus each record's end offset within the file.
fn write_wal(tag: &str, deltas: &[GraphDelta]) -> (Vec<u8>, Vec<usize>) {
    let dir = tmp_dir(tag);
    let mut cfg = PersistConfig::new(&dir);
    cfg.snapshot_every = 0; // WAL only: every record survives to the file
    let (mut p, recovery) = Persistence::open(cfg).unwrap();
    assert_eq!(recovery.deltas.len(), 0);
    let mut ends = Vec::new();
    let mut at = 0usize;
    for d in deltas {
        at += p.append_delta(d).unwrap() as usize;
        ends.push(at);
    }
    drop(p);
    let wal = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("wal-"))
                .unwrap_or(false)
        })
        .expect("the WAL file exists");
    let bytes = std::fs::read(wal).unwrap();
    assert_eq!(bytes.len(), *ends.last().unwrap());
    let _ = std::fs::remove_dir_all(&dir);
    (bytes, ends)
}

/// Recover from a state dir holding exactly `bytes` as its WAL; returns
/// the recovered deltas (as JSON strings) and the dropped-byte count.
fn recover(tag: &str, bytes: &[u8]) -> (Vec<String>, u64, Option<String>) {
    let dir = tmp_dir(tag);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("wal-0.log"), bytes).unwrap();
    let (_p, recovery) = Persistence::open(PersistConfig::new(&dir)).unwrap();
    let got = recovery
        .deltas
        .iter()
        .map(|d| d.to_json().to_string())
        .collect();
    let out = (got, recovery.dropped_bytes, recovery.dropped_note);
    let _ = std::fs::remove_dir_all(&dir);
    out
}

/// Satellite: deterministic torn-tail sweep.  Cut the WAL at **every**
/// byte offset of the final record — plus the exact record boundary —
/// and require longest-valid-prefix recovery with an honest drop report.
#[test]
fn torn_tail_recovers_longest_valid_prefix_at_every_cut_point() {
    let deltas = workload();
    let want: Vec<String> = deltas.iter().map(|d| d.to_json().to_string()).collect();
    let (bytes, ends) = write_wal("torn_src", &deltas);
    let boundary = ends[ends.len() - 2]; // end of the penultimate record
    for cut in boundary..=bytes.len() {
        let (got, dropped, note) = recover("torn_cut", &bytes[..cut]);
        if cut == bytes.len() {
            assert_eq!(got, want, "uncut log must replay fully");
            assert_eq!(dropped, 0);
        } else {
            assert_eq!(
                got,
                want[..want.len() - 1],
                "cut at {cut}: must keep exactly the full records"
            );
            assert_eq!(
                dropped,
                (cut - boundary) as u64,
                "cut at {cut}: drop report must match the torn bytes"
            );
            if cut > boundary {
                assert!(note.is_some(), "cut at {cut}: a drop needs a reason");
            }
        }
    }
}

/// Satellite: corrupting any single byte of the final record (flip, not
/// truncate) must also fall back to the valid prefix — the checksum, not
/// luck, is what rejects the record.
#[test]
fn corrupt_final_record_is_dropped_by_checksum() {
    let deltas = workload();
    let want: Vec<String> = deltas.iter().map(|d| d.to_json().to_string()).collect();
    let (bytes, ends) = write_wal("corrupt_src", &deltas);
    let boundary = ends[ends.len() - 2];
    // flipping a bit anywhere in the final record's payload or header must
    // not survive; step through it (every 3rd byte keeps the sweep fast)
    for at in (boundary..bytes.len()).step_by(3) {
        let mut mutated = bytes.clone();
        mutated[at] ^= 0x20;
        let (got, _dropped, _note) = recover("corrupt_at", &mutated);
        // the corrupted record must never replay as something else: either
        // it is dropped (prefix) or the flip hit redundant JSON whitespace
        // — there is none in our encoder, so it must be dropped
        assert_eq!(
            got,
            want[..want.len() - 1],
            "byte {at}: corrupted record leaked into recovery"
        );
    }
}

/// Property: a cut at a *random* offset anywhere in the log keeps exactly
/// the records that end at or before the cut.  Replayable via
/// `A2Q_PROP_SEED` like every property in the repo.
#[test]
fn random_cut_keeps_exactly_the_complete_prefix() {
    let deltas = workload();
    let want: Vec<String> = deltas.iter().map(|d| d.to_json().to_string()).collect();
    let (bytes, ends) = write_wal("prop_src", &deltas);
    property("wal random cut", 60, |g: &mut Gen| {
        let cut = g.usize_range(0, bytes.len() + 1);
        let complete = ends.iter().filter(|&&e| e <= cut).count();
        let (got, dropped, _note) = recover("prop_cut", &bytes[..cut]);
        assert_eq!(got, want[..complete], "cut at {cut}");
        let valid = if complete == 0 { 0 } else { ends[complete - 1] };
        assert_eq!(dropped, (cut - valid) as u64, "cut at {cut}");
    });
}

/// End-to-end tie-in: recovery from a torn log serves the same bits as a
/// continuous session that applied only the surviving prefix.
#[test]
fn torn_log_recovery_matches_a_prefix_only_session() {
    let deltas = workload();
    let dir = tmp_dir("tie_in");
    let mut cfg = PersistConfig::new(&dir);
    cfg.snapshot_every = 0;
    let (exec, _) = build(None).with_persistence(cfg.clone()).unwrap();
    for d in &deltas {
        exec.apply_delta(d).unwrap();
    }
    drop(exec);
    // tear off the final record's last 7 bytes ("crashed mid-write")
    let wal = dir.join("wal-0.log");
    let bytes = std::fs::read(&wal).unwrap();
    std::fs::write(&wal, &bytes[..bytes.len() - 7]).unwrap();

    let (torn, restore) = build(None).with_persistence(cfg).unwrap();
    assert_eq!(restore.replayed_deltas, deltas.len() - 1);
    assert!(restore.dropped_bytes > 0);

    let clean = build(None);
    for d in &deltas[..deltas.len() - 1] {
        clean.apply_delta(d).unwrap();
    }
    let all: Vec<u32> = (0..34).collect();
    assert_eq!(
        torn.run_node_batch(&all).unwrap(),
        clean.run_node_batch(&all).unwrap(),
        "torn recovery must equal the prefix-only session bitwise"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
