//! Dynamic-graph serving parity: random [`GraphDelta`] sequences applied
//! **incrementally** to a live `NativeExecutor` (CSR row repair, GCN-weight
//! splice, sort-free plan reconstruction, L-hop-frontier logits patching,
//! online NNS bitwidth assignment for appended nodes) must be **bitwise
//! identical** to rebuilding everything from scratch over the post-delta
//! edge set: the aggregation plan, the fp logits, and the true-integer-path
//! logits.  The executors run at 1 and 4 threads while the rebuilds run at
//! the *other* thread count, so every assertion simultaneously checks
//! incremental-vs-rebuild and thread-count invariance.
//!
//! The from-scratch reference uses the executor's own post-delta
//! quantization parameters (`resident_quant_params`): the NNS-assigned
//! `(step, bits)` of appended nodes are resident model state, exactly like
//! the learned entries — a rebuild with the same state must reproduce the
//! served logits bit-for-bit.
//!
//! Runs on the `util::prop` harness: `A2Q_PROP_SEED=<seed>` replays one
//! failing case exactly (the failure message prints the seed),
//! `A2Q_PROP_CASES=<n>` overrides every property's case count.

use std::collections::BTreeSet;

use a2q::coordinator::{BatchExecutor, NativeExecutor};
use a2q::gnn::{forward_fp_with, forward_int_with, GnnModel, GraphInput, LayerParams, QuantMethod};
use a2q::graph::delta::GraphDelta;
use a2q::graph::generate::preferential_attachment;
use a2q::graph::io::{Dataset, NodeData};
use a2q::graph::norm::EdgeForm;
use a2q::graph::Csr;
use a2q::quant::mixed::NodeQuantParams;
use a2q::tensor::simd::{self, Isa};
use a2q::tensor::Matrix;
use a2q::util::json::Json;
use a2q::util::prop::{property, Gen};
use a2q::util::rng::Rng;
use a2q::util::threadpool::ParallelConfig;

fn random_matrix(g: &mut Gen, rows: usize, cols: usize) -> Matrix<f32> {
    Matrix::from_vec(rows, cols, g.vec_normal(rows * cols, 0.5)).unwrap()
}

fn node_quant(g: &mut Gen, n: usize, signed: bool) -> NodeQuantParams {
    let steps = g.vec_uniform(n, 0.02, 0.1);
    let bits: Vec<u8> = (0..n).map(|_| g.usize_range(2, 9) as u8).collect();
    NodeQuantParams::new(steps, bits, signed).unwrap()
}

fn random_model(
    g: &mut Gen,
    arch: &str,
    n: usize,
    in_dim: usize,
    hidden: usize,
    out_dim: usize,
    n_layers: usize,
) -> GnnModel {
    let mut layers = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let d_in = if l == 0 { in_dim } else { hidden };
        let d_out = if l == n_layers - 1 { out_dim } else { hidden };
        let lay = match arch {
            "gcn" => LayerParams {
                w: Some(random_matrix(g, d_in, d_out)),
                b: g.vec_uniform(d_out, -0.1, 0.1),
                w_steps: g.vec_uniform(d_out, 0.02, 0.08),
                feat: Some(node_quant(g, n, l == 0)),
                ..Default::default()
            },
            "gin" => LayerParams {
                w: Some(random_matrix(g, d_in, hidden)),
                b: g.vec_uniform(hidden, -0.1, 0.1),
                w_steps: g.vec_uniform(hidden, 0.02, 0.08),
                w2: Some(random_matrix(g, hidden, d_out)),
                b2: g.vec_uniform(d_out, -0.1, 0.1),
                w2_steps: g.vec_uniform(d_out, 0.02, 0.08),
                eps: g.f32_range(0.0, 0.2),
                feat: Some(node_quant(g, n, l == 0)),
                feat2: Some(node_quant(g, n, false)),
                ..Default::default()
            },
            other => panic!("unexpected arch {other}"),
        };
        layers.push(lay);
    }
    GnnModel {
        name: format!("delta-{arch}"),
        arch: arch.to_string(),
        dataset: "synthetic".to_string(),
        method: QuantMethod::A2q,
        layers,
        head: None,
        dq_steps: Vec::new(),
        skip_input_quant: false,
        node_level: true,
        num_nodes: n,
        in_dim,
        out_dim,
        heads: 1,
        graph_capacity: 0,
        accuracy: 0.0,
        avg_bits: 4.0,
        expected_head: Vec::new(),
        manifest: Json::Null,
    }
}

fn node_dataset(csr: Csr, features: Vec<f32>, feat_dim: usize) -> Dataset {
    let n = csr.num_nodes();
    Dataset::Node(NodeData {
        name: "synthetic".into(),
        csr,
        num_features: feat_dim,
        num_classes: 2,
        features,
        labels: vec![0; n],
        train_mask: vec![false; n],
        val_mask: vec![false; n],
        test_mask: vec![false; n],
    })
}

fn random_delta(
    g: &mut Gen,
    n_cur: usize,
    in_dim: usize,
    edge_set: &BTreeSet<(u32, u32)>,
) -> GraphDelta {
    let add_nodes = g.usize_range(0, 3);
    let n_new = n_cur + add_nodes;
    let existing: Vec<(u32, u32)> = edge_set.iter().copied().collect();
    let add_edges: Vec<(u32, u32)> = (0..g.usize_range(0, 10))
        .map(|_| (g.usize_range(0, n_new) as u32, g.usize_range(0, n_new) as u32))
        .collect();
    let mut remove_edges: Vec<(u32, u32)> = if existing.is_empty() {
        Vec::new()
    } else {
        (0..g.usize_range(0, 5))
            .map(|_| existing[g.usize_range(0, existing.len())])
            .collect()
    };
    // one absent removal exercises the no-op path
    remove_edges.push((g.usize_range(0, n_new) as u32, g.usize_range(0, n_new) as u32));
    GraphDelta {
        add_nodes,
        new_features: g.vec_normal(add_nodes * in_dim, 0.5),
        add_edges,
        remove_edges,
    }
}

/// Clone the original model with the executor's post-delta quantization
/// parameters and node count — the state a from-scratch rebuild serves.
fn extended_model(original: &GnnModel, exec: &NativeExecutor, n_cur: usize) -> GnnModel {
    let mut m = original.clone();
    for (lay, (f, f2)) in m.layers.iter_mut().zip(exec.resident_quant_params()) {
        lay.feat = f;
        lay.feat2 = f2;
    }
    m.num_nodes = n_cur;
    m
}

#[test]
fn incremental_deltas_bitwise_match_full_rebuild() {
    property("delta sequence == rebuild (plan/fp/int)", 6, |g: &mut Gen| {
        let n0 = g.usize_range(16, 48);
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        let csr0 = preferential_attachment(&mut rng, n0, 2);
        let in_dim = g.usize_range(2, 6);
        let hidden = g.usize_range(2, 8);
        let out_dim = g.usize_range(2, 5);
        let n_layers = g.usize_range(1, 4);
        let features0 = g.vec_normal(n0 * in_dim, 0.5);

        let one = ParallelConfig::serial();
        let four = ParallelConfig {
            threads: 4,
            min_rows_per_task: 8,
            ..ParallelConfig::serial()
        };

        for arch in ["gcn", "gin"] {
            let model = random_model(g, arch, n0, in_dim, hidden, out_dim, n_layers);
            let ds = node_dataset(csr0.clone(), features0.clone(), in_dim);
            // fp executor patched at 4 threads, verified against 1-thread
            // rebuilds; int executor the other way around — every compare
            // is simultaneously a threads ∈ {1,4} bitwise check
            let exec_fp = NativeExecutor::new(model.clone(), Some(&ds))
                .unwrap()
                .with_parallelism(four);
            let exec_int = NativeExecutor::new(model.clone(), Some(&ds))
                .unwrap()
                .with_int_path(true)
                .with_parallelism(one);
            // warm the fp session (delta patches a served cache); leave the
            // int session cold (delta warms it itself)
            exec_fp.run_node_batch(&[0]).unwrap();

            let mut edge_set: BTreeSet<(u32, u32)> = csr0.edge_list().into_iter().collect();
            let mut features = features0.clone();
            let mut n_cur = n0;

            for step in 0..2 {
                let delta = random_delta(g, n_cur, in_dim, &edge_set);
                exec_fp.apply_delta(&delta).unwrap();
                exec_int.apply_delta(&delta).unwrap();
                // mirror the delta set-wise for the from-scratch rebuild
                n_cur += delta.add_nodes;
                features.extend_from_slice(&delta.new_features);
                for &e in &delta.add_edges {
                    edge_set.insert(e);
                }
                for &e in &delta.remove_edges {
                    edge_set.remove(&e);
                }

                let full: Vec<(u32, u32)> = edge_set.iter().copied().collect();
                let rebuilt = Csr::from_edges(n_cur, &full).unwrap();
                let ef = EdgeForm::from_csr(&rebuilt);

                // 1. plans bitwise identical
                let plan = exec_fp.resident_plan().expect("resident plan");
                assert_eq!(plan, ef.plan(), "{arch} step {step}: plan diverged");
                assert_eq!(exec_fp.resident_nodes(), n_cur);

                // 2. fp logits bitwise identical to a 1-thread rebuild
                let input = GraphInput::node_level(&features, in_dim, &ef);
                let fp_model = extended_model(&model, &exec_fp, n_cur);
                let want_fp = forward_fp_with(&fp_model, &input, &one);
                let all: Vec<u32> = (0..n_cur as u32).collect();
                let got_fp = exec_fp.run_node_batch(&all).unwrap();
                for (v, row) in got_fp.iter().enumerate() {
                    assert_eq!(
                        row.as_slice(),
                        want_fp.row(v),
                        "{arch} step {step}: fp row {v} diverged"
                    );
                }

                // 3. int logits bitwise identical to a 4-thread rebuild
                let int_model = extended_model(&model, &exec_int, n_cur);
                let want_int = forward_int_with(&int_model, &input, &four);
                let got_int = exec_int.run_node_batch(&all).unwrap();
                for (v, row) in got_int.iter().enumerate() {
                    assert_eq!(
                        row.as_slice(),
                        want_int.row(v),
                        "{arch} step {step}: int row {v} diverged"
                    );
                }
            }
        }
    });
}

/// ISSUE 5: after deltas append nodes (whose `(step, bits)` arrive via the
/// online NNS assignment, not training), the executor's *post-delta*
/// resident parameters must drive the bucketed kernels bitwise identically
/// to the scratch-unpack reference at threads ∈ {1, 4}.  Together with
/// `incremental_deltas_bitwise_match_full_rebuild` (patcher vs bucketed
/// rebuild, threads crossed) this closes the loop: patcher == bucketed ==
/// scratch on the extended parameter set.
#[test]
fn post_delta_params_drive_bucketed_kernel_like_scratch() {
    property("post-delta slab bucketed == scratch", 5, |g: &mut Gen| {
        let n0 = g.usize_range(12, 36);
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        let csr0 = preferential_attachment(&mut rng, n0, 2);
        let in_dim = g.usize_range(2, 5);
        let features0 = g.vec_normal(n0 * in_dim, 0.5);
        let model = random_model(g, "gin", n0, in_dim, 4, 3, 2);
        let ds = node_dataset(csr0.clone(), features0.clone(), in_dim);
        let exec = NativeExecutor::new(model, Some(&ds))
            .unwrap()
            .with_int_path(true)
            .with_parallelism(ParallelConfig::serial());

        let add_nodes = g.usize_range(1, 4);
        let delta = GraphDelta {
            add_nodes,
            new_features: g.vec_normal(add_nodes * in_dim, 0.5),
            add_edges: (0..add_nodes as u32)
                .flat_map(|i| [(n0 as u32 + i, i), (i, n0 as u32 + i)])
                .collect(),
            remove_edges: vec![],
        };
        exec.apply_delta(&delta).unwrap();
        let n_cur = n0 + add_nodes;

        // every per-node map the executor now holds — learned entries plus
        // the NNS-assigned ones for the appended nodes — must feed the
        // bucketed kernels exactly like the reference kernel
        let w_cols = g.usize_range(1, 8);
        for (f, f2) in exec.resident_quant_params() {
            for p in [f, f2].into_iter().flatten() {
                assert_eq!(p.len(), n_cur, "params not extended to appended nodes");
                let fdim = g.usize_range(1, 12);
                let x = g.vec_normal(n_cur * fdim, 0.6);
                let (codes, _) = p.quantize_codes(&x, fdim);
                let packed =
                    a2q::quant::pack::pack_rows(&codes, &p.steps, &p.bits, fdim, p.signed);
                let w = Matrix::from_vec(
                    fdim,
                    w_cols,
                    (0..fdim * w_cols).map(|i| (i % 15) as i32 - 7).collect(),
                )
                .unwrap();
                // scalar-pinned oracle, compared across threads × ISA
                let want = packed
                    .matmul_i32_scratch(&w, &ParallelConfig::serial().with_simd(Isa::Scalar));
                for isa in simd::parity_isas() {
                    for threads in [1usize, 4] {
                        let cfg = ParallelConfig {
                            threads,
                            min_rows_per_task: 2,
                            simd: isa,
                        };
                        assert_eq!(
                            packed.matmul_i32(&w, &cfg).data,
                            want.data,
                            "t={threads} isa={}: post-delta bucketed != scratch",
                            isa.name()
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn appended_nodes_serve_like_retrained_residents() {
    // After a delta appends nodes, a *fresh* executor built over the
    // post-delta graph + the post-delta (NNS-extended) parameters must
    // serve the exact logits the incrementally-updated executor serves —
    // i.e. assignment is persistent resident state, not a per-request hack.
    property("unseen-node assignment is persistent state", 4, |g: &mut Gen| {
        let n0 = g.usize_range(12, 30);
        let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
        let csr0 = preferential_attachment(&mut rng, n0, 2);
        let in_dim = g.usize_range(2, 5);
        let features0 = g.vec_normal(n0 * in_dim, 0.5);
        let model = random_model(g, "gin", n0, in_dim, 4, 3, 2);
        let ds = node_dataset(csr0.clone(), features0.clone(), in_dim);
        let exec = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());

        let delta = GraphDelta {
            add_nodes: 2,
            new_features: g.vec_normal(2 * in_dim, 0.5),
            add_edges: vec![
                (n0 as u32, 0),
                (0, n0 as u32),
                (n0 as u32 + 1, 1),
                (1, n0 as u32 + 1),
            ],
            remove_edges: vec![],
        };
        exec.apply_delta(&delta).unwrap();
        let n_cur = n0 + 2;
        let mut features = features0.clone();
        features.extend_from_slice(&delta.new_features);
        let mut edges = csr0.edge_list();
        edges.extend_from_slice(&delta.add_edges);
        let rebuilt = Csr::from_edges(n_cur, &edges).unwrap();

        let ext = extended_model(&model, &exec, n_cur);
        let fresh = NativeExecutor::new(
            ext,
            Some(&node_dataset(rebuilt, features, in_dim)),
        )
        .unwrap()
        .with_parallelism(ParallelConfig::serial());

        let all: Vec<u32> = (0..n_cur as u32).collect();
        assert_eq!(
            exec.run_node_batch(&all).unwrap(),
            fresh.run_node_batch(&all).unwrap(),
            "fresh session over extended state diverged from patched session"
        );
    });
}
