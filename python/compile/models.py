"""L2: GCN / GIN / GAT in functional JAX with pluggable A²Q quantization.

The three architectures follow Table 4 of the paper (MPNN forms):

    GCN:  h_i = Σ_{j∈N(i)∪{i}} (d_i d_j)^{-1/2} x_j ;  x' = ReLU(W h + b)
    GIN:  h_i = (1+ε) x_i + Σ_{j∈N(i)} x_j          ;  x' = MLP(h)
    GAT:  h_i = Σ_{j∈N(i)∪{i}} α_ij x_j             ;  x' = W h + b  (ELU between layers)

Quantization points (§3.1):
* the [N, F] feature map entering each update-phase matmul is fake-quantized
  with per-node learnable (s_i, b_i) — "aggregation-aware";
* weights are fake-quantized per output column at fixed 4 bits;
* GAT attention coefficients are quantized at fixed 4 bits (per A.6);
* the normalized adjacency is NOT quantized (Proof 2).

One forward function serves FP32 / A²Q(local|global) / DQ-INT4 / binary /
manual by swapping the feature-quantizer closure built in ``make_quantizer``.
Graph-level models quantize through the Nearest Neighbor Strategy instead of
per-node parameters (§3.3).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as Q

Array = jnp.ndarray


@dataclass(frozen=True)
class ModelConfig:
    arch: str  # "gcn" | "gin" | "gat"
    in_dim: int
    hidden: int
    out_dim: int
    layers: int = 2
    heads: int = 8  # GAT only
    skip: bool = False
    dropout: float = 0.5
    readout: str = "none"  # "none" (node-level) | "mean" | "sum"


@dataclass(frozen=True)
class QuantConfig:
    method: str = "fp32"  # fp32|a2q|a2q_global|dq|binary|manual
    wbits: float = 4.0
    abits: float = 4.0  # attention-coefficient bits (GAT)
    nns: bool = False  # graph-level: use NNS groups instead of per-node
    nns_m: int = 1000
    skip_input_quant: bool = False  # binary bag-of-words inputs (Cora/CiteSeer)
    init_bits: float = 4.0
    learn_bits: bool = True  # ablation "no-lr-b"
    learn_step: bool = True  # ablation "no-lr-s"


# ---------------------------------------------------------------------------
# Edge preprocessing
# ---------------------------------------------------------------------------


@dataclass
class EdgeData:
    """Static edge arrays for one (possibly batched block-diagonal) graph.

    Registered as a jax pytree (arrays are children) so it can be passed as
    a jit argument — closing over it as a constant makes XLA constant-fold
    full-graph gathers at compile time (minutes on large graphs).
    """

    src: Array  # [E] i32
    dst: Array  # [E] i32
    gcn_w: Array  # [E] f32  (d_i d_j)^{-1/2} with self-loops, 0 on padding
    sum_w: Array  # [E] f32  1.0 for real edges, 0 on padding (GIN/GAT mask)
    num_nodes: int
    node2graph: Array | None = None  # [N] i32 (graph-level batching)
    num_graphs: int = 1
    node_mask: Array | None = None  # [N] f32 1=real node


def _edges_flatten(e: "EdgeData"):
    return (
        (e.src, e.dst, e.gcn_w, e.sum_w, e.node2graph, e.node_mask),
        (e.num_nodes, e.num_graphs),
    )


def _edges_unflatten(aux, children):
    src, dst, gcn_w, sum_w, node2graph, node_mask = children
    return EdgeData(
        src=src, dst=dst, gcn_w=gcn_w, sum_w=sum_w,
        num_nodes=aux[0], node2graph=node2graph,
        num_graphs=aux[1], node_mask=node_mask,
    )


jax.tree_util.register_pytree_node(EdgeData, _edges_flatten, _edges_unflatten)


def build_edges(indptr: np.ndarray, indices: np.ndarray) -> EdgeData:
    """Node-level: full-graph edges + self-loops + GCN normalisation."""
    n = indptr.shape[0] - 1
    deg = np.diff(indptr).astype(np.int64)
    dst = np.repeat(np.arange(n, dtype=np.int64), deg)
    src = indices.astype(np.int64)
    # self loops
    src = np.concatenate([src, np.arange(n)])
    dst = np.concatenate([dst, np.arange(n)])
    dtilde = np.bincount(dst, minlength=n).astype(np.float64)
    w = 1.0 / np.sqrt(dtilde[src] * dtilde[dst])
    sum_w = np.ones_like(w)
    # the self-loop messages don't count for GIN's neighbour sum
    sum_w[-n:] = 0.0
    return EdgeData(
        src=jnp.asarray(src, jnp.int32),
        dst=jnp.asarray(dst, jnp.int32),
        gcn_w=jnp.asarray(w, jnp.float32),
        sum_w=jnp.asarray(sum_w, jnp.float32),
        num_nodes=n,
    )


def pad_graph_batch(
    graphs: list, max_nodes: int, max_edges: int, feat_dim: int
) -> tuple[np.ndarray, EdgeData]:
    """Block-diagonal batch of small graphs padded to static shapes.

    Padding nodes are routed to graph slot ``G`` (one extra dummy segment)
    and padding edges get zero weight, so the readout over real segments is
    exact.  This same packing is what the rust coordinator's dynamic batcher
    produces at serving time.
    """
    g = len(graphs)
    feats = np.zeros((max_nodes, feat_dim), dtype=np.float32)
    node2graph = np.full(max_nodes, g, dtype=np.int64)
    node_mask = np.zeros(max_nodes, dtype=np.float32)
    src_l, dst_l, w_l, sw_l = [], [], [], []
    off = 0
    for gi, gr in enumerate(graphs):
        n = gr.num_nodes
        assert off + n <= max_nodes, "batch overflow"
        feats[off : off + n] = gr.features
        node2graph[off : off + n] = gi
        node_mask[off : off + n] = 1.0
        s, d = gr.edge_list()
        deg_in = np.bincount(d, minlength=n) + 1.0
        # self loops per graph
        s_all = np.concatenate([s, np.arange(n)])
        d_all = np.concatenate([d, np.arange(n)])
        w = 1.0 / np.sqrt(deg_in[s_all] * deg_in[d_all])
        sw = np.ones_like(w)
        sw[-n:] = 0.0
        src_l.append(s_all + off)
        dst_l.append(d_all + off)
        w_l.append(w)
        sw_l.append(sw)
        off += n
    src = np.concatenate(src_l) if src_l else np.zeros(0, np.int64)
    dst = np.concatenate(dst_l) if dst_l else np.zeros(0, np.int64)
    w = np.concatenate(w_l) if w_l else np.zeros(0, np.float64)
    sw = np.concatenate(sw_l) if sw_l else np.zeros(0, np.float64)
    e = src.shape[0]
    assert e <= max_edges, f"edge overflow {e} > {max_edges}"
    pad_e = max_edges - e
    src = np.concatenate([src, np.zeros(pad_e, np.int64)])
    dst = np.concatenate([dst, np.zeros(pad_e, np.int64)])
    w = np.concatenate([w, np.zeros(pad_e)])
    sw = np.concatenate([sw, np.zeros(pad_e)])
    edges = EdgeData(
        src=jnp.asarray(src, jnp.int32),
        dst=jnp.asarray(dst, jnp.int32),
        gcn_w=jnp.asarray(w, jnp.float32),
        sum_w=jnp.asarray(sw, jnp.float32),
        num_nodes=max_nodes,
        node2graph=jnp.asarray(node2graph, jnp.int32),
        num_graphs=g,
        node_mask=jnp.asarray(node_mask, jnp.float32),
    )
    return feats, edges


def aggregate(x: Array, edges: EdgeData, weights: Array) -> Array:
    """out[d] = Σ_e w_e · x[src_e]  — the aggregation phase (fixed-point
    additions on hardware; Â itself is never quantized, Proof 2)."""
    msgs = x[edges.src] * weights[:, None]
    return jnp.zeros((edges.num_nodes, x.shape[1]), x.dtype).at[edges.dst].add(msgs)


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------


def _glorot(rng, fan_in, fan_out):
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jax.random.uniform(rng, (fan_in, fan_out), minval=-lim, maxval=lim)


def layer_dims(cfg: ModelConfig) -> list[tuple[int, int]]:
    """(in, out) dims of each GNN layer (GAT hidden is per-head × heads)."""
    dims = []
    d = cfg.in_dim
    for l in range(cfg.layers):
        out = cfg.out_dim if l == cfg.layers - 1 and cfg.readout == "none" else cfg.hidden
        dims.append((d, out))
        d = out
    return dims


def init_params(rng, cfg: ModelConfig) -> dict:
    """Model weights. GIN layers carry a 2-layer MLP + ε; GAT carries
    per-head attention vectors; graph-level models add a readout MLP head."""
    params: dict[str, Any] = {"layers": []}
    keys = jax.random.split(rng, cfg.layers * 4 + 2)
    ki = 0
    for l, (fi, fo) in enumerate(layer_dims(cfg)):
        if cfg.arch == "gin":
            lay = {
                "w1": _glorot(keys[ki], fi, fo),
                "b1": jnp.zeros(fo),
                "w2": _glorot(keys[ki + 1], fo, fo),
                "b2": jnp.zeros(fo),
                "eps": jnp.zeros(()),
            }
        elif cfg.arch == "gat":
            # concat heads on hidden layers; single head on the output layer
            last = l == cfg.layers - 1 and cfg.readout == "none"
            heads_l = 1 if last else cfg.heads
            fh = fo if last else max(fo // cfg.heads, 1)
            lay = {
                "w": _glorot(keys[ki], fi, fh * heads_l),
                "b": jnp.zeros(fh * heads_l),
                "a_src": 0.1 * jax.random.normal(keys[ki + 1], (heads_l, fh)),
                "a_dst": 0.1 * jax.random.normal(keys[ki + 2], (heads_l, fh)),
            }
        else:  # gcn
            lay = {"w": _glorot(keys[ki], fi, fo), "b": jnp.zeros(fo)}
        params["layers"].append(lay)
        ki += 4
    if cfg.readout != "none":
        fi = layer_dims(cfg)[-1][1]
        params["head"] = {
            "w1": _glorot(keys[ki], fi, cfg.hidden),
            "b1": jnp.zeros(cfg.hidden),
            "w2": _glorot(keys[ki + 1], cfg.hidden, cfg.out_dim),
            "b2": jnp.zeros(cfg.out_dim),
        }
    return params


def init_qparams(rng, cfg: ModelConfig, qcfg: QuantConfig, num_nodes: int) -> dict:
    """Quantizer parameters: per-node (s, b) per quantized map (node-level)
    or m NNS groups (graph-level); per-column weight steps per matmul."""
    if qcfg.method == "fp32":
        return {}
    qp: dict[str, Any] = {"feat": [], "w": []}
    if cfg.arch == "gin":
        qp["feat2"] = []  # second MLP matmul input (analysed in Fig. 4(e))
    keys = jax.random.split(rng, 4 * cfg.layers + 4)
    ki = 0
    n_or_m = qcfg.nns_m if qcfg.nns else num_nodes
    for l, (fi, fo) in enumerate(layer_dims(cfg)):
        init = Q.init_feature_qparams(keys[ki], n_or_m, qcfg.init_bits)
        qp["feat"].append({"s": init.step, "b": init.bits})
        ki += 1
        if cfg.arch == "gin":
            init2 = Q.init_feature_qparams(keys[ki], n_or_m, qcfg.init_bits)
            qp["feat2"].append({"s": init2.step, "b": init2.bits})
            wcols = [fo, fo]
        elif cfg.arch == "gat":
            last = l == cfg.layers - 1 and cfg.readout == "none"
            heads_l = 1 if last else cfg.heads
            fh = fo if last else max(fo // cfg.heads, 1)
            wcols = [fh * heads_l]
        else:
            wcols = [fo]
        qp["w"].append([Q.init_weight_steps(keys[ki + i], c) for i, c in enumerate(wcols)])
        ki += 2
    if cfg.readout != "none":
        qp["head_w"] = [
            Q.init_weight_steps(keys[ki], cfg.hidden),
            Q.init_weight_steps(keys[ki + 1], cfg.out_dim),
        ]
        init = Q.init_feature_qparams(keys[ki + 2], n_or_m, qcfg.init_bits)
        qp["head_feat"] = {"s": init.step, "b": init.bits}
    if cfg.arch == "gat":
        qp["attn"] = [jnp.asarray(0.05) for _ in range(cfg.layers)]
    # DQ/binary: scalar steps per layer
    if qcfg.method == "dq":
        qp["dq_s"] = [jnp.asarray(0.05) for _ in range(cfg.layers + 1)]
    return qp


# ---------------------------------------------------------------------------
# Quantizer dispatch
# ---------------------------------------------------------------------------


def make_feature_quantizer(
    qcfg: QuantConfig,
    qp: dict,
    layer: int,
    *,
    signed: bool,
    key: str = "feat",
    impl: str = "jnp",
) -> Callable[[Array, Array | None], tuple[Array, Array | None]]:
    """Returns q(x, prot_mask) -> (x_q, nns_idx).  Closures capture qparams
    so jax.grad w.r.t. qp flows through the returned function.

    ``impl="pallas"`` routes the forward through the L1 Pallas kernels
    (inference/export only — no custom VJP on that path)."""

    method = qcfg.method

    def fp32(x, prot):
        return x, None

    if method == "fp32":
        return fp32

    if method == "binary":

        def binq(x, prot):
            return Q.binary_quantize(x), None

        return binq

    if method == "dq":

        def dqq(x, prot):
            s = qp["dq_s"][layer]
            mask = prot if prot is not None else jnp.zeros(x.shape[0])
            return Q.dq_quantize(x, s, mask, qcfg.abits, signed), None

        return dqq

    entry = qp[key][layer] if isinstance(qp[key], list) else qp[key]
    s, b = entry["s"], entry["b"]
    if not qcfg.learn_step:
        s = jax.lax.stop_gradient(s)
    if not qcfg.learn_bits or method == "manual":
        b = jax.lax.stop_gradient(b)
    grad_mode = "local" if method == "a2q" else "global"

    if qcfg.nns:
        if impl == "pallas":
            from .kernels import nns as nns_kernel

            def nnsq_pl(x, prot):
                return nns_kernel.nns_quantize(x, s, b, signed=signed)

            return nnsq_pl

        def nnsq(x, prot):
            xq, idx = Q.nns_quantize_train(x, s, b, signed)
            return xq, idx

        return nnsq

    if impl == "pallas":
        from .kernels import aaq as aaq_kernel

        def a2q_pl(x, prot):
            return aaq_kernel.aaq_quantize(x, s, b, signed=signed), None

        return a2q_pl

    def a2q(x, prot):
        return Q.a2q_quantize(x, s, b, signed, grad_mode), None

    return a2q


def quant_w(qcfg: QuantConfig, steps: Array | None, w: Array) -> Array:
    if qcfg.method == "fp32" or steps is None:
        return w
    if qcfg.method == "binary":
        return Q.binary_quantize(w.T).T
    return Q.quantize_weights(w, steps, qcfg.wbits)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def segment_softmax(logits: Array, seg: Array, num_segments: int) -> Array:
    mx = jax.ops.segment_max(logits, seg, num_segments=num_segments)
    ex = jnp.exp(logits - mx[seg])
    den = jax.ops.segment_sum(ex, seg, num_segments=num_segments)
    return ex / (den[seg] + 1e-16)


def forward(
    params: dict,
    qp: dict,
    x: Array,
    edges: EdgeData,
    cfg: ModelConfig,
    qcfg: QuantConfig,
    *,
    train: bool = False,
    rng: Array | None = None,
    prot_mask: Array | None = None,
    collect: bool = False,
    impl: str = "jnp",
):
    """Full model forward.  Returns (output, aux) where output is
    [N, out_dim] node logits or [G, out_dim] graph predictions, and aux
    carries per-layer hidden states / NNS indices when ``collect``.
    """
    aux: dict[str, Any] = {"hidden": [], "aggregated": [], "nns_idx": []}
    h = x
    signed = True  # input features may be negative
    for l, lay in enumerate(params["layers"]):
        skip_q = l == 0 and qcfg.skip_input_quant
        if not skip_q and qcfg.method != "fp32":
            quant = make_feature_quantizer(qcfg, qp, l, signed=signed, impl=impl)
            h, idx = quant(h, prot_mask)
            if collect:
                aux["nns_idx"].append(idx)
        if train and cfg.dropout > 0 and rng is not None:
            rng, sub = jax.random.split(rng)
            keep = jax.random.bernoulli(sub, 1.0 - cfg.dropout, h.shape)
            h = jnp.where(keep, h / (1.0 - cfg.dropout), 0.0)

        if cfg.arch == "gcn":
            agg = aggregate(h, edges, edges.gcn_w)
            if collect:
                aux["aggregated"].append(agg)
            wq = quant_w(qcfg, qp["w"][l][0] if qp else None, lay["w"])
            out = agg @ wq + lay["b"]
        elif cfg.arch == "gin":
            neigh = aggregate(h, edges, edges.sum_w)
            agg = (1.0 + lay["eps"]) * h + neigh
            if collect:
                aux["aggregated"].append(agg)
            w1 = quant_w(qcfg, qp["w"][l][0] if qp else None, lay["w1"])
            hid = jax.nn.relu(agg @ w1 + lay["b1"])
            # second MLP matmul gets its own feature quantization (the paper
            # analyses exactly this map in Fig. 4(e))
            if qcfg.method != "fp32":
                key2 = "feat2" if "feat2" in qp else "feat"
                quant2 = make_feature_quantizer(
                    qcfg, qp, l, signed=False, key=key2, impl=impl
                )
                hid, _ = quant2(hid, prot_mask)
            w2 = quant_w(qcfg, qp["w"][l][1] if qp else None, lay["w2"])
            out = hid @ w2 + lay["b2"]
        else:  # gat
            fh = lay["a_src"].shape[1]
            heads = lay["a_src"].shape[0]
            wq = quant_w(qcfg, qp["w"][l][0] if qp else None, lay["w"])
            z = (h @ wq).reshape(-1, heads, fh)  # [N, H, Fh]
            e_src = jnp.einsum("nhf,hf->nh", z, lay["a_src"])
            e_dst = jnp.einsum("nhf,hf->nh", z, lay["a_dst"])
            logits = jax.nn.leaky_relu(
                e_src[edges.src] + e_dst[edges.dst], negative_slope=0.2
            )  # [E, H]
            # mask padding edges with -inf before softmax
            logits = jnp.where(
                (edges.gcn_w > 0)[:, None] | (edges.sum_w > 0)[:, None],
                logits,
                -1e9,
            )
            alpha = jax.vmap(
                lambda lg: segment_softmax(lg, edges.dst, edges.num_nodes),
                in_axes=1,
                out_axes=1,
            )(logits)  # [E, H]
            if qcfg.method not in ("fp32", "binary"):
                alpha = Q.lsq_quantize(alpha, qp["attn"][l], qcfg.abits, False)
            msgs = z[edges.src] * alpha[:, :, None]  # [E, H, Fh]
            agg = (
                jnp.zeros((edges.num_nodes, heads, fh))
                .at[edges.dst]
                .add(msgs)
                .reshape(edges.num_nodes, heads * fh)
            )
            if collect:
                aux["aggregated"].append(agg)
            out = agg + lay["b"]

        last = l == cfg.layers - 1
        if cfg.skip and out.shape == h.shape:
            out = out + h
        if not last or cfg.readout != "none":
            out = jax.nn.relu(out) if cfg.arch != "gat" else jax.nn.elu(out)
            signed = cfg.arch == "gat"  # ReLU outputs are non-negative
        if collect:
            aux["hidden"].append(out)
        h = out

    if cfg.readout == "none":
        return h, aux

    # graph-level readout: mean over real nodes per segment
    n2g = edges.node2graph
    g = edges.num_graphs
    mask = edges.node_mask[:, None]
    sums = jax.ops.segment_sum(h * mask, n2g, num_segments=g + 1)[:g]
    if cfg.readout == "mean":
        cnt = jax.ops.segment_sum(edges.node_mask, n2g, num_segments=g + 1)[:g]
        pooled = sums / jnp.maximum(cnt, 1.0)[:, None]
    else:
        pooled = sums
    head = params["head"]
    hq = pooled
    if qcfg.method not in ("fp32", "binary", "dq") and "head_feat" in qp:
        quant = make_feature_quantizer(qcfg, qp, 0, signed=True, key="head_feat")
        hq, _ = quant(hq, None)
    w1 = quant_w(qcfg, qp["head_w"][0] if qp and "head_w" in qp else None, head["w1"])
    w2 = quant_w(qcfg, qp["head_w"][1] if qp and "head_w" in qp else None, head["w2"])
    z = jax.nn.relu(hq @ w1 + head["b1"])
    return z @ w2 + head["b2"], aux


def feature_bits_and_dims(qp: dict, cfg: ModelConfig) -> tuple[list, list]:
    """Bits arrays + feature dims for the memory penalty / average-bits."""
    if not qp or "feat" not in qp:
        return [], []
    bits = [entry["b"] for entry in qp["feat"]]
    dims = [fi for (fi, _fo) in layer_dims(cfg)]
    if "feat2" in qp:  # GIN: the hidden map feeding the MLP's 2nd matmul
        bits.extend(entry["b"] for entry in qp["feat2"])
        dims.extend(fo for (_fi, fo) in layer_dims(cfg))
    if "head_feat" in qp:
        bits.append(qp["head_feat"]["b"])
        dims.append(layer_dims(cfg)[-1][1])
    return bits, dims
