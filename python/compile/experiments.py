"""Full paper-table experiment sweep (build-time; cached & resumable).

Regenerates the training-side numbers behind every table/figure of the paper
(DESIGN.md §4 experiment index).  Each cell is a ``TrainConfig``; results are
cached in ``artifacts/results`` as JSON, so interrupting and re-running
`make experiments` resumes where it stopped.  A²Q cells additionally dump
``.bits.bin`` files (per-node learned bitwidths) consumed by the rust
cycle-accurate accelerator simulator for the speedup columns.

Ordering matters on the 1-core budget: Tables 1-2 (headline) run first,
ablations afterwards.
"""

from __future__ import annotations

import os
import sys
import time

from . import models as M
from . import train as T
from .aot import write_bits_file
from .train import TrainConfig

# ---------------------------------------------------------------------------
# Table 1 — node-level (paper: GCN/GAT/GIN × Cora/CiteSeer/PubMed/arxiv)
# ---------------------------------------------------------------------------


def table1_cells() -> list[TrainConfig]:
    cells = []
    rows = [
        # (arch, dataset, hidden, layers, epochs, target_bits, seeds)
        ("gcn", "synth-cora", 16, 2, 200, 1.7, (0, 1)),
        ("gat", "synth-cora", 64, 2, 200, 2.0, (0, 1)),
        ("gcn", "synth-citeseer", 16, 2, 200, 1.9, (0, 1)),
        ("gin", "synth-citeseer", 16, 2, 200, 2.5, (0, 1)),
        ("gat", "synth-pubmed", 64, 2, 150, 2.1, (0,)),
        ("gcn", "synth-arxiv", 64, 3, 100, 2.65, (0,)),
    ]
    for arch, ds, hid, lay, ep, tgt, seeds in rows:
        for method in ("fp32", "dq", "a2q"):
            for seed in seeds:
                cells.append(
                    TrainConfig(
                        dataset=ds, arch=arch, method=method, hidden=hid,
                        layers=lay, epochs=ep, target_avg_bits=tgt, seed=seed,
                        lr=0.005 if arch == "gat" else 0.01,
                        dropout=0.6 if arch == "gat" else 0.5,
                        lam=5.0,
                    )
                )
    return cells


# ---------------------------------------------------------------------------
# Table 2 — graph-level (NNS)
# ---------------------------------------------------------------------------


def table2_cells() -> list[TrainConfig]:
    cells = []
    rows = [
        ("gcn", "synth-mnist", 64, 4, 20, 3.5),
        ("gin", "synth-mnist", 64, 4, 20, 3.75),
        ("gcn", "synth-cifar10", 64, 4, 20, 3.3),
        ("gat", "synth-cifar10", 64, 4, 20, 3.7),
        ("gcn", "synth-zinc", 64, 4, 30, 3.7),
        ("gin", "synth-reddit-b", 64, 4, 30, 3.5),
    ]
    for arch, ds, hid, lay, ep, tgt in rows:
        for method in ("fp32", "dq", "a2q"):
            # quantized runs ramp slowly post-calibration (the quantizer
            # must adapt before the task loss moves) — give them 2× epochs
            ep_m = ep if method == "fp32" else ep * 2
            cells.append(
                TrainConfig(
                    dataset=ds, arch=arch, method=method, hidden=hid,
                    layers=lay, epochs=ep_m, target_avg_bits=tgt, seed=0,
                    lr=0.003 if arch == "gat" else 0.005,
                    lam=0.5, penalty_warmup=5, batch_graphs=32,
                )
            )
    return cells


# ---------------------------------------------------------------------------
# Table 3 — ablations
# ---------------------------------------------------------------------------


def table3_cells() -> list[TrainConfig]:
    base = dict(dataset="synth-cora", arch="gin", hidden=16, layers=2,
                epochs=200, target_avg_bits=2.4, lam=5.0, seed=0)
    cells = [
        # no-lr: neither step nor bits learned (init only)
        TrainConfig(method="a2q", learn_step=False, learn_bits=False, **base),
        # no-lr-b: only step learned (bits fixed at 4)
        TrainConfig(method="a2q", learn_step=True, learn_bits=False, **base),
        # no-lr-s: only bits learned
        TrainConfig(method="a2q", learn_step=False, learn_bits=True, **base),
        # lr-all
        TrainConfig(method="a2q", learn_step=True, learn_bits=True, **base),
    ]
    # Local vs Global gradient on GCN-CiteSeer
    for method in ("a2q", "a2q_global"):
        cells.append(
            TrainConfig(dataset="synth-citeseer", arch="gcn", method=method,
                        hidden=16, layers=2, epochs=200, target_avg_bits=1.9,
                        lam=5.0, seed=0)
        )
    return cells


# ---------------------------------------------------------------------------
# Table 11 — NNS group count sweep (GIN-REDDIT-B)
# ---------------------------------------------------------------------------


def table11_cells() -> list[TrainConfig]:
    return [
        TrainConfig(dataset="synth-reddit-b", arch="gin", method="a2q",
                    hidden=64, layers=4, epochs=20, target_avg_bits=4.0,
                    lam=0.25, penalty_warmup=5, nns_m=m, seed=0,
                    batch_graphs=32, lr=0.005)
        for m in (100, 400, 800, 1000, 1500)
    ]


# ---------------------------------------------------------------------------
# Tables 13/14 — depth & skip-connection ablation (GCN-Cora)
# ---------------------------------------------------------------------------


def table13_cells() -> list[TrainConfig]:
    cells = []
    for layers in (3, 4, 5, 6):
        for skip in (False, True):
            for method in ("fp32", "a2q"):
                cells.append(
                    TrainConfig(dataset="synth-cora", arch="gcn", method=method,
                                hidden=16, layers=layers, skip=skip, epochs=200,
                                target_avg_bits=3.0, lam=2.0, seed=0)
                )
    return cells


# ---------------------------------------------------------------------------
# Table 16 — binary quantization comparison
# ---------------------------------------------------------------------------


def table16_cells() -> list[TrainConfig]:
    cells = []
    for ds in ("synth-cora", "synth-citeseer"):
        for arch in ("gcn", "gin", "gat"):
            hid = 64 if arch == "gat" else 16
            cells.append(
                TrainConfig(dataset=ds, arch=arch, method="binary", hidden=hid,
                            layers=2, epochs=200, seed=0,
                            lr=0.005 if arch == "gat" else 0.01)
            )
            # a2q counterpart for GIN/GAT rows not already in Table 1
            cells.append(
                TrainConfig(dataset=ds, arch=arch, method="a2q", hidden=hid,
                            layers=2, epochs=200, target_avg_bits=2.0,
                            lam=5.0, seed=0,
                            lr=0.005 if arch == "gat" else 0.01)
            )
    return cells


# ---------------------------------------------------------------------------
# Fig. 5 — learned vs manual bit assignment
# ---------------------------------------------------------------------------


def fig5_cells() -> list[TrainConfig]:
    cells = []
    for arch, ds in (("gcn", "synth-cora"), ("gin", "synth-citeseer")):
        for avg in (2.2, 3.0):
            cells.append(
                TrainConfig(dataset=ds, arch=arch, method="manual", hidden=16,
                            layers=2, epochs=200, manual_avg_bits=avg,
                            target_avg_bits=avg, seed=0)
            )
            cells.append(
                TrainConfig(dataset=ds, arch=arch, method="a2q", hidden=16,
                            layers=2, epochs=200, target_avg_bits=avg,
                            lam=5.0, seed=0)
            )
    return cells


SUITES = {
    "table1": table1_cells,
    "table2": table2_cells,
    "table3": table3_cells,
    "table11": table11_cells,
    "table13": table13_cells,
    "table16": table16_cells,
    "fig5": fig5_cells,
}


def dump_bits(cfg: TrainConfig) -> None:
    """Write the .bits.bin for an A²Q cell (accelerator sim input)."""
    try:
        tree, mcfg, qcfg, _ds = T.rebuild_tree(cfg)
    except Exception as exc:  # noqa: BLE001 — missing npz etc.
        print(f"  bits skip ({exc})")
        return
    path = T.tree_path(cfg).replace(".npz", ".bits.bin")
    write_bits_file(tree, mcfg, qcfg, path)


def main() -> None:
    only = sys.argv[1:] or list(SUITES)
    t_start = time.time()
    for suite in only:
        cells = SUITES[suite]()
        print(f"=== {suite}: {len(cells)} cells ===", flush=True)
        for i, cfg in enumerate(cells):
            t0 = time.time()
            hit, _ = T.cached(cfg)
            blob, _path = T.train_any(cfg)
            state = "cached" if hit is not None else f"{time.time()-t0:.0f}s"
            print(
                f"[{suite} {i+1}/{len(cells)}] {cfg.tag()} -> "
                f"{blob['metric_name']}={blob['accuracy']:.4f} "
                f"bits={blob['avg_bits']:.2f} ({state})",
                flush=True,
            )
            if cfg.method in ("a2q", "a2q_global", "manual") and hit is None:
                dump_bits(cfg)
    print(f"sweep done in {(time.time()-t_start)/60:.1f} min")


if __name__ == "__main__":
    main()
