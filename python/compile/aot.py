"""AOT export: trained quantized GNNs → HLO text + weights for the rust L3.

This is the compile-path boundary of the three-layer stack.  For each model
variant we emit into ``artifacts/models/``:

* ``<variant>.hlo.txt``      — HLO **text** of the quantized inference
  forward (jax → StableHLO → XlaComputation → text; serialized protos from
  jax ≥ 0.5 carry 64-bit instruction ids that xla_extension 0.5.1 rejects);
* ``<variant>.weights.bin``  — little-endian f32 flat tensors;
* ``<variant>.manifest.json``— tensor table, quant params, dataset link,
  expected-output test vector for the rust integration tests;
* ``<variant>.bits.bin``     — per-node learned bitwidths (u8) per feature
  map, consumed by the cycle-accurate accelerator simulator.

Export signature (node-level):   f(x, src, dst, gcn_w, sum_w) -> logits
Export signature (graph-level):  f(x, src, dst, gcn_w, sum_w, n2g, mask) -> preds
Edge arrays are runtime inputs (never baked) so the rust coordinator feeds
its own batches; weights are baked as HLO constants.

``--pallas`` additionally exports a variant whose feature quantization runs
through the L1 Pallas kernel (interpret mode) lowered into the same HLO —
the §Perf ablation comparing kernelized vs XLA-fused quantization.
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets as D
from . import models as M
from . import train as T


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Weights serialisation
# ---------------------------------------------------------------------------


def flatten_tree(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out.append((name, np.asarray(leaf, dtype=np.float32)))
    return out


def write_weights(tree, path: str):
    tensors = []
    offset = 0
    with open(path, "wb") as fh:
        for name, arr in flatten_tree(tree):
            fh.write(arr.astype("<f4").tobytes())
            tensors.append({"name": name, "shape": list(arr.shape), "offset": offset})
            offset += arr.size
    return tensors


def write_bits_file(tree, mcfg, qcfg, path: str):
    """Per-map learned bitwidths for the accelerator simulator (u8)."""
    bits_list, dims = M.feature_bits_and_dims(tree["qp"], mcfg)
    if qcfg.skip_input_quant and bits_list:
        bits_list, dims = bits_list[1:], dims[1:]
    with open(path, "wb") as fh:
        fh.write(b"A2QB")
        fh.write(struct.pack("<I", len(bits_list)))
        for b, dim in zip(bits_list, dims):
            br = np.asarray(jnp.round(jnp.clip(b, 1.0, 8.0))).astype(np.uint8)
            fh.write(struct.pack("<II", br.shape[0], int(dim)))
            fh.write(br.tobytes())
    return len(bits_list)


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------


def export_variant(
    cfg: T.TrainConfig, out_dir: str, *, use_pallas: bool = False, suffix: str = ""
) -> str:
    """Train (or reuse cached) ``cfg`` and export the inference artifact."""
    result, _ = T.train_any(cfg)  # ensures npz exists
    tree, mcfg, qcfg, ds = T.rebuild_tree(cfg)
    name = f"{cfg.arch}-{cfg.dataset}-{cfg.method}{suffix}"
    os.makedirs(out_dir, exist_ok=True)

    node_level = cfg.dataset in D.NODE_SPECS
    if node_level:
        sample_edges = M.build_edges(ds.indptr, ds.indices)
        x_np = np.asarray(ds.features)
        n_out = ds.num_nodes
    else:
        # serving shape: fixed batch capacity (nodes/edges/graph slots)
        cap_g = 16
        mean_n = int(np.mean([g.num_nodes for g in ds.graphs]))
        cap_n = int(cap_g * mean_n * 2)
        cap_e = int(cap_n * 6)
        feats, sample_edges = M.pad_graph_batch(
            [ds.graphs[i] for i in range(cap_g)], cap_n, cap_e, ds.num_features
        )
        x_np = feats
        n_out = cap_g

    impl = "pallas" if use_pallas else "jnp"

    # Weights are passed as runtime PARAMETERS, not baked constants: the
    # HLO *text* interchange elides large literals ("constant({...})"),
    # which the text parser reloads as zeros.  The rust runtime appends the
    # weights.bin tensors (manifest order == tree_flatten order) after the
    # data inputs on every call.
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    n_data = 5 if node_level else 7

    def infer(*args):
        data = args[:n_data]
        wtree = jax.tree_util.tree_unflatten(treedef, args[n_data:])
        x, src, dst, gcn_w, sum_w = data[:5]
        n2g = data[5] if not node_level else None
        mask = data[6] if not node_level else None
        e = M.EdgeData(
            src=src, dst=dst, gcn_w=gcn_w, sum_w=sum_w,
            num_nodes=x.shape[0], node2graph=n2g,
            num_graphs=(sample_edges.num_graphs if not node_level else 1),
            node_mask=mask,
        )
        out, _ = M.forward(
            wtree["model"], wtree["qp"], x, e, mcfg, qcfg,
            train=False,
            prot_mask=jnp.zeros(x.shape[0]),
            impl=impl,
        )
        return out

    x = jnp.asarray(x_np)
    args = (
        x,
        sample_edges.src,
        sample_edges.dst,
        sample_edges.gcn_w,
        sample_edges.sum_w,
    )
    if not node_level:
        args = args + (sample_edges.node2graph, sample_edges.node_mask)
    args = args + tuple(leaves)

    jitted = jax.jit(infer)
    lowered = jitted.lower(*args)
    # jax DCEs unused args before lowering; record which logical inputs
    # survive (sorted = positional order of the HLO entry parameters).
    kept = sorted(lowered._lowering.compile_args["kept_var_idx"])
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as fh:
        fh.write(to_hlo_text(lowered))

    # ground-truth logits for the rust integration test (first 8 rows)
    expected = np.asarray(jitted(*args))
    head = expected[: min(8, expected.shape[0])].reshape(-1)

    weights_path = os.path.join(out_dir, f"{name}.weights.bin")
    tensors = write_weights(tree, weights_path)
    bits_path = os.path.join(out_dir, f"{name}.bits.bin")
    n_maps = (
        write_bits_file(tree, mcfg, qcfg, bits_path)
        if tree["qp"] and "feat" in tree["qp"]
        else 0
    )

    manifest = {
        "name": name,
        "arch": cfg.arch,
        "dataset": cfg.dataset,
        "method": cfg.method,
        "impl": impl,
        "layers": cfg.layers,
        "hidden": cfg.hidden,
        "heads": cfg.heads,
        "node_level": node_level,
        "num_data_inputs": n_data,
        "param_map": kept,
        "num_nodes": int(x_np.shape[0]),
        "num_edges": int(sample_edges.src.shape[0]),
        "in_dim": int(x_np.shape[1]),
        "out_dim": int(expected.shape[1]),
        "num_outputs": int(n_out),
        "graph_capacity": (0 if node_level else sample_edges.num_graphs),
        "hlo": os.path.basename(hlo_path),
        "weights_bin": os.path.basename(weights_path),
        "bits_bin": os.path.basename(bits_path) if n_maps else None,
        "num_bit_maps": n_maps,
        "tensors": tensors,
        "accuracy": result["accuracy"],
        "metric_name": result["metric_name"],
        "avg_bits": result["avg_bits"],
        "compression": result["compression"],
        "expected_head": [float(v) for v in head],
        "skip_input_quant": qcfg.skip_input_quant,
    }
    man_path = os.path.join(out_dir, f"{name}.manifest.json")
    with open(man_path, "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"exported {name}: acc={result['accuracy']:.4f} bits={result['avg_bits']:.2f}")
    return man_path


QUICKSTART = [
    T.TrainConfig(dataset="synth-cora", arch="gcn", method="a2q", epochs=200,
                  hidden=16, lam=5.0, target_avg_bits=1.7),
    T.TrainConfig(dataset="synth-cora", arch="gcn", method="fp32", epochs=200,
                  hidden=16),
    T.TrainConfig(dataset="synth-cora", arch="gcn", method="dq", epochs=200,
                  hidden=16),
    T.TrainConfig(dataset="synth-zinc", arch="gin", method="a2q", epochs=30,
                  hidden=64, layers=4, lam=0.5, target_avg_bits=3.7,
                  penalty_warmup=5, lr=0.005, batch_graphs=32),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="artifacts dir")
    args = ap.parse_args()
    root = args.out or os.path.join(T._repo_root(), "artifacts")
    D.build_all(os.path.join(root, "data"))
    models_dir = os.path.join(root, "models")
    manifests = []
    for cfg in QUICKSTART:
        manifests.append(export_variant(cfg, models_dir))
    # Pallas-kernelized twin of the headline variant (perf ablation)
    manifests.append(
        export_variant(QUICKSTART[0], models_dir, use_pallas=True, suffix="-pallas")
    )
    index = {"models": [os.path.basename(m).replace(".manifest.json", "") for m in manifests]}
    with open(os.path.join(models_dir, "index.json"), "w") as fh:
        json.dump(index, fh, indent=1)
    print(f"wrote {len(manifests)} model artifacts to {models_dir}")


if __name__ == "__main__":
    main()
