"""Build-time QAT training for A²Q and all baselines (L2).

Runs entirely at `make artifacts` / `make experiments` time — never on the
rust request path.  Implements:

* node-level semi-supervised training (full batch, masked NLL) with the
  Local Gradient method (§3.2) for A²Q;
* graph-level training with NNS (§3.3), static-shape padded batches;
* baselines: FP32, DQ-INT4 (degree-based protection), binary (Bi-GCN-like),
  manual mixed-precision assignment (Fig. 5 ablation);
* ablations: no-lr / no-lr-b / no-lr-s / lr-all (Table 3), Local vs Global
  (Table 3), NNS group-count sweep (Table 11), depth & skip (Tables 13/14);
* the Fig. 3 gradient-sparsity probe.

Results are cached as JSON under ``artifacts/results`` keyed by config, so
re-running `make experiments` only trains missing cells.
"""

from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import asdict, dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets as D
from . import models as M
from . import quantize as Q

# ---------------------------------------------------------------------------
# Hand-rolled Adam (no optax available offline) with per-group learning rates
# ---------------------------------------------------------------------------


def adam_init(tree):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, tree)
    return {"m": zeros, "v": zeros, "t": 0}


def adam_update(tree, grads, state, lr_tree, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads
    )
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t
    new = jax.tree_util.tree_map(
        lambda p, mm, vv, lr: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps),
        tree,
        m,
        v,
        lr_tree,
    )
    return new, {"m": m, "v": v, "t": t}


def lr_tree_for(tree, lr_model, lr_step, lr_bits):
    """Per-leaf learning rate: quantizer bits / steps get their own lr
    (paper A.6 trains them with dedicated learning rates)."""

    def assign(path, leaf):
        names = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
        names = [str(n) for n in names]
        if "qp" in names:
            if "b" in names:
                return jnp.full_like(leaf, lr_bits)
            return jnp.full_like(leaf, lr_step)
        return jnp.full_like(leaf, lr_model)

    return jax.tree_util.tree_map_with_path(assign, tree)


def clamp_qparams(qp):
    """Keep steps positive and bits in the learnable range after each step."""

    def fix(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        if "b" in names:
            return jnp.clip(leaf, Q.BITS_LO, Q.BITS_HI)
        if "s" in names or "w" in names or "dq_s" in names or "attn" in names:
            return jnp.maximum(leaf, Q.MIN_STEP * 10)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, qp)


# ---------------------------------------------------------------------------
# Config / results
# ---------------------------------------------------------------------------


@dataclass
class TrainConfig:
    dataset: str = "synth-cora"
    arch: str = "gcn"
    method: str = "a2q"  # fp32|a2q|a2q_global|dq|binary|manual|mixed_manual
    layers: int = 2
    hidden: int = 16
    heads: int = 8
    skip: bool = False
    dropout: float = 0.5
    epochs: int = 200
    lr: float = 0.01
    lr_step: float = 0.01
    lr_bits: float = 0.03
    weight_decay: float = 5e-4
    lam: float = 5.0  # λ memory-penalty factor (Eq. 6)
    penalty_warmup: int = 30  # epochs before L_mem kicks in (stabilises QAT)
    target_avg_bits: float = 2.0  # drives M_target in Eq. 5
    manual_avg_bits: float = 0.0  # manual baseline bit budget
    seed: int = 0
    nns_m: int = 1000
    batch_graphs: int = 32
    init_bits: float = 4.0
    learn_bits: bool = True
    learn_step: bool = True

    def key(self) -> str:
        blob = json.dumps(asdict(self), sort_keys=True).encode()
        return hashlib.sha1(blob).hexdigest()[:12]

    def tag(self) -> str:
        return f"{self.arch}-{self.dataset}-{self.method}-s{self.seed}"


@dataclass
class TrainResult:
    config: dict
    accuracy: float  # test accuracy (or -MAE for regression)
    metric_name: str
    avg_bits: float
    compression: float
    train_seconds: float
    epochs_run: int
    history: list  # (epoch, train_loss, val_metric)
    bits_hist: list  # learned-bit histogram (counts of 1..8), feature maps
    grad_zero_frac: float = -1.0  # Fig. 3 probe (node-level only)


def _results_dir() -> str:
    d = os.environ.get("A2Q_RESULTS", os.path.join(_repo_root(), "artifacts", "results"))
    os.makedirs(d, exist_ok=True)
    return d


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def cached(cfg: TrainConfig):
    path = os.path.join(_results_dir(), f"{cfg.tag()}-{cfg.key()}.json")
    if os.path.exists(path):
        with open(path) as fh:
            return json.load(fh), path
    return None, path


def save_tree(tree, path: str) -> None:
    """Flatten a params pytree into an .npz keyed by the leaf path string."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {jax.tree_util.keystr(p): np.asarray(v) for p, v in flat}
    np.savez(path, **arrays)


def load_tree(template, path: str):
    """Restore arrays saved by ``save_tree`` into ``template``'s structure."""
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = [jnp.asarray(data[jax.tree_util.keystr(p)]) for p, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def tree_path(cfg: TrainConfig) -> str:
    return os.path.join(_results_dir(), f"{cfg.tag()}-{cfg.key()}.npz")


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def build_qcfg(cfg: TrainConfig, ds_binary_feat: bool, graph_level: bool) -> M.QuantConfig:
    method = cfg.method
    if method in ("mixed_manual",):
        method = "manual"
    return M.QuantConfig(
        method={"a2q_global": "a2q_global", "a2q": "a2q"}.get(method, method),
        nns=graph_level and method in ("a2q", "a2q_global", "manual"),
        nns_m=cfg.nns_m,
        skip_input_quant=ds_binary_feat,
        init_bits=cfg.init_bits,
        learn_bits=cfg.learn_bits and method not in ("manual",),
        learn_step=cfg.learn_step,
    )


def mem_target_kb(cfg: TrainConfig, dims: list[int], counts: list[int]) -> float:
    total_elems = sum(d * n for d, n in zip(dims, counts))
    return cfg.target_avg_bits * total_elems / (8.0 * 1024.0)


def calibrate_qparams(tree, mcfg, qcfg, x, edges, cfg):
    """Data-driven step-size initialisation (LSQ-style calibration).

    The paper's N(0.01, 0.01) init assumes citation-network magnitudes; on
    feature scales far from that (superpixel intensities ≈ 1.0) the initial
    q_max ≈ 0.07·(2^{b-1}-1) clips catastrophically and QAT cannot recover
    within the epoch budget.  We run one FP32 forward, measure each feature
    map's mean |x|, and set  s = 2·E|x| / (2^{b-1}-1)  per map.  NNS groups
    are spread log-uniformly so their q_max covers [0.1, 4]×max|x|.
    """
    qp = tree["qp"]
    if not qp:
        return tree
    fp_qcfg = M.QuantConfig(method="fp32")
    _, aux = M.forward(
        tree["model"], {}, x, edges, mcfg, fp_qcfg, train=False, collect=True
    )
    # input to layer l: x for l=0, post-activation hidden[l-1] otherwise
    layer_inputs = [x] + aux["hidden"][:-1]
    levels = 2.0 ** (cfg.init_bits - 1.0) - 1.0

    def step_for(map_x):
        m = float(jnp.mean(jnp.abs(map_x))) + 1e-6
        return 2.0 * m / levels

    def spread(map_x, m_groups):
        mx = float(jnp.max(jnp.abs(map_x))) + 1e-6
        qmaxes = np.logspace(np.log10(0.1 * mx), np.log10(4.0 * mx), m_groups)
        return jnp.asarray((qmaxes / levels).astype(np.float32))

    if "feat" in qp:
        for l, entry in enumerate(qp["feat"]):
            ref = layer_inputs[min(l, len(layer_inputs) - 1)]
            if qcfg.nns:
                entry["s"] = spread(ref, entry["s"].shape[0])
            else:
                entry["s"] = jnp.full_like(entry["s"], step_for(ref))
    if "feat2" in qp:
        for l, entry in enumerate(qp["feat2"]):
            ref = aux["hidden"][min(l, len(aux["hidden"]) - 1)]
            if qcfg.nns:
                entry["s"] = spread(ref, entry["s"].shape[0])
            else:
                entry["s"] = jnp.full_like(entry["s"], step_for(ref))
    if "head_feat" in qp:
        ref = aux["hidden"][-1]
        if qcfg.nns:
            qp["head_feat"]["s"] = spread(ref, qp["head_feat"]["s"].shape[0])
        else:
            qp["head_feat"]["s"] = jnp.full_like(
                qp["head_feat"]["s"], step_for(ref)
            )
    if "dq_s" in qp:
        for l in range(len(qp["dq_s"])):
            ref = layer_inputs[min(l, len(layer_inputs) - 1)]
            qp["dq_s"][l] = jnp.asarray(step_for(ref))
    return {"model": tree["model"], "qp": qp}


def bits_histogram(qp, skip_first: bool = False) -> list:
    """Histogram of learned (rounded) bits over quantized feature maps.
    ``skip_first`` drops the unused layer-0 quantizer when the input is
    binary bag-of-words (Cora/CiteSeer analogues)."""
    if not qp or "feat" not in qp:
        return []
    counts = np.zeros(9, dtype=np.int64)
    entries = list(qp["feat"][1 if skip_first else 0 :])
    entries += list(qp.get("feat2", []))
    if "head_feat" in qp:
        entries.append(qp["head_feat"])
    for entry in entries:
        b = np.asarray(jnp.round(jnp.clip(entry["b"], Q.BITS_LO, Q.BITS_HI)))
        for v in range(1, 9):
            counts[v] += int((b == v).sum())
    return counts[1:].tolist()


def effective_avg_bits(qp, cfg_model: M.ModelConfig, qcfg: M.QuantConfig) -> float:
    """Memory-weighted average bits over quantized feature maps (skipping the
    unquantized bag-of-words input when applicable)."""
    bits, dims = M.feature_bits_and_dims(qp, cfg_model)
    if qcfg.skip_input_quant and bits:
        bits, dims = bits[1:], dims[1:]
    if not bits:
        return 32.0
    return float(Q.average_bits(bits, dims))


# ---------------------------------------------------------------------------
# Node-level training
# ---------------------------------------------------------------------------


def train_node(cfg: TrainConfig, use_cache: bool = True):
    hit, path = cached(cfg)
    if hit is not None and use_cache:
        return hit, path
    t0 = time.time()
    ds = D.make_node_dataset(cfg.dataset, seed=0)  # graph fixed across seeds
    edges = M.build_edges(ds.indptr, ds.indices)
    deg = jnp.asarray(ds.in_degrees(), jnp.float32)

    mcfg = M.ModelConfig(
        arch=cfg.arch,
        in_dim=ds.num_features,
        hidden=cfg.hidden,
        out_dim=ds.num_classes,
        layers=cfg.layers,
        heads=cfg.heads,
        skip=cfg.skip,
        dropout=cfg.dropout,
        readout="none",
    )
    qcfg = build_qcfg(cfg, ds.binary_features, graph_level=False)

    rng = jax.random.PRNGKey(cfg.seed)
    rng, k1, k2 = jax.random.split(rng, 3)
    params = M.init_params(k1, mcfg)
    qp = M.init_qparams(k2, mcfg, qcfg, ds.num_nodes)
    if cfg.method in ("manual", "mixed_manual") and qp:
        avg = cfg.manual_avg_bits or cfg.target_avg_bits
        for entry in qp["feat"]:
            entry["b"] = Q.manual_bits_by_degree(np.asarray(deg), avg)

    x = jnp.asarray(ds.features)
    y = jnp.asarray(ds.labels)
    train_mask = jnp.asarray(ds.train_mask)
    val_mask = jnp.asarray(ds.val_mask)
    test_mask = jnp.asarray(ds.test_mask)

    bits_list, dim_list = M.feature_bits_and_dims(qp, mcfg)
    if qcfg.skip_input_quant and bits_list:
        bits_ix = list(range(1, len(bits_list)))
    else:
        bits_ix = list(range(len(bits_list)))
    dims_kept = [dim_list[i] for i in bits_ix]
    m_target = mem_target_kb(cfg, dims_kept, [ds.num_nodes] * len(dims_kept))

    # DQ protection probabilities ∝ in-degree percentile (Tailor et al.)
    if cfg.method == "dq":
        pct = jnp.argsort(jnp.argsort(deg)) / max(ds.num_nodes - 1, 1)
        prot_p = 0.1 + 0.8 * pct
    else:
        prot_p = None

    tree = {"model": params, "qp": qp}
    tree = calibrate_qparams(tree, mcfg, qcfg, x, edges, cfg)
    opt = adam_init(tree)
    lr_tree = lr_tree_for(tree, cfg.lr, cfg.lr_step, cfg.lr_bits)

    def loss_fn(tree, rng, prot, x, edges, lam):
        logits, _ = M.forward(
            tree["model"], tree["qp"], x, edges, mcfg, qcfg,
            train=True, rng=rng, prot_mask=prot,
        )
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.sum(
            jnp.where(train_mask, logp[jnp.arange(y.shape[0]), y], 0.0)
        ) / jnp.sum(train_mask)
        l2 = sum(jnp.sum(w**2) for w in jax.tree_util.tree_leaves(tree["model"]))
        loss = nll + cfg.weight_decay * l2
        if tree["qp"] and "feat" in tree["qp"] and cfg.method in ("a2q", "a2q_global", "manual"):
            bl, dl = M.feature_bits_and_dims(tree["qp"], mcfg)
            bl = [bl[i] for i in bits_ix]
            dl = [dl[i] for i in bits_ix]
            if bl and qcfg.learn_bits:
                loss = loss + lam * Q.memory_penalty(bl, dl, m_target)
        return loss, nll

    @jax.jit
    def step(tree, opt, rng, prot, x, edges, lam):
        rng, sub = jax.random.split(rng)
        (loss, nll), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            tree, sub, prot, x, edges, lam
        )
        tree, opt = adam_update(tree, grads, opt, lr_tree)
        tree = {"model": tree["model"], "qp": clamp_qparams(tree["qp"])}
        return tree, opt, rng, nll

    @jax.jit
    def evaluate(tree, mask, x, edges):
        logits, _ = M.forward(
            tree["model"], tree["qp"], x, edges, mcfg, qcfg,
            train=False, prot_mask=jnp.zeros(x.shape[0]),
        )
        pred = jnp.argmax(logits, -1)
        return jnp.sum(jnp.where(mask, (pred == y).astype(jnp.float32), 0.0)) / jnp.sum(mask)

    history = []
    best_val, best_test = -1.0, 0.0
    zeros = jnp.zeros(ds.num_nodes)
    for epoch in range(cfg.epochs):
        if prot_p is not None:
            rng, sub = jax.random.split(rng)
            prot = jax.random.bernoulli(sub, prot_p).astype(jnp.float32)
        else:
            prot = zeros
        lam = jnp.asarray(cfg.lam if epoch >= cfg.penalty_warmup else 0.0)
        tree, opt, rng, nll = step(tree, opt, rng, prot, x, edges, lam)
        if epoch % 10 == 0 or epoch == cfg.epochs - 1:
            va = float(evaluate(tree, val_mask, x, edges))
            te = float(evaluate(tree, test_mask, x, edges))
            history.append((epoch, float(nll), va))
            if va >= best_val:
                best_val, best_test = va, te

    # Fig. 3 probe: fraction of nodes with exactly-zero task gradient
    def task_loss_of_x(xx, edges):
        logits, _ = M.forward(
            tree["model"], tree["qp"], xx, edges, mcfg, qcfg,
            train=False, prot_mask=zeros,
        )
        logp = jax.nn.log_softmax(logits)
        return -jnp.sum(
            jnp.where(train_mask, logp[jnp.arange(y.shape[0]), y], 0.0)
        ) / jnp.sum(train_mask)

    gx = jax.jit(jax.grad(task_loss_of_x))(x, edges)
    grad_norms = jnp.linalg.norm(gx, axis=-1)
    zero_frac = float(jnp.mean((grad_norms == 0.0).astype(jnp.float32)))

    avg_bits = (
        effective_avg_bits(tree["qp"], mcfg, qcfg)
        if cfg.method in ("a2q", "a2q_global", "manual")
        else {"fp32": 32.0, "dq": 4.0, "binary": 1.0}.get(cfg.method, 4.0)
    )
    result = TrainResult(
        config=asdict(cfg),
        accuracy=best_test,
        metric_name="accuracy",
        avg_bits=avg_bits,
        compression=32.0 / avg_bits,
        train_seconds=time.time() - t0,
        epochs_run=cfg.epochs,
        history=history,
        bits_hist=bits_histogram(tree["qp"], skip_first=qcfg.skip_input_quant),
        grad_zero_frac=zero_frac,
    )
    blob = asdict(result)
    hit, path = cached(cfg)
    save_tree(tree, tree_path(cfg))
    with open(path, "w") as fh:
        json.dump(blob, fh)
    return blob, path


# ---------------------------------------------------------------------------
# Graph-level training (NNS)
# ---------------------------------------------------------------------------


def _batch_plan(ds: D.GraphDataset, batch_graphs: int):
    """Static-shape batch packing: graphs in fixed batches, padded to the
    dataset-wide per-batch max (keeps every jitted step the same shape)."""
    order = np.arange(ds.num_graphs)
    batches = [order[i : i + batch_graphs] for i in range(0, ds.num_graphs, batch_graphs)]
    max_nodes = 0
    max_edges = 0
    for b in batches:
        nn = sum(ds.graphs[i].num_nodes for i in b)
        ee = sum(ds.graphs[i].indices.shape[0] + ds.graphs[i].num_nodes for i in b)
        max_nodes = max(max_nodes, nn)
        max_edges = max(max_edges, ee)
    return batches, max_nodes, max_edges


def train_graph(cfg: TrainConfig, use_cache: bool = True):
    hit, path = cached(cfg)
    if hit is not None and use_cache:
        return hit, path
    t0 = time.time()
    ds = D.make_graph_dataset(cfg.dataset, seed=0)
    regression = ds.num_classes == 0
    out_dim = 1 if regression else ds.num_classes

    g = ds.num_graphs
    rng_np = np.random.default_rng(cfg.seed)
    perm = rng_np.permutation(g)
    n_tr, n_va = int(0.8 * g), int(0.1 * g)
    tr_ids, va_ids, te_ids = (
        perm[:n_tr],
        perm[n_tr : n_tr + n_va],
        perm[n_tr + n_va :],
    )

    mcfg = M.ModelConfig(
        arch=cfg.arch,
        in_dim=ds.num_features,
        hidden=cfg.hidden,
        out_dim=out_dim,
        layers=cfg.layers,
        heads=cfg.heads,
        skip=cfg.skip,
        dropout=0.0,
        readout="mean",
    )
    qcfg = build_qcfg(cfg, False, graph_level=True)

    rng = jax.random.PRNGKey(cfg.seed)
    rng, k1, k2 = jax.random.split(rng, 3)
    params = M.init_params(k1, mcfg)
    qp = M.init_qparams(k2, mcfg, qcfg, cfg.nns_m)

    def pack(ids):
        sub = [ds.graphs[i] for i in ids]
        batches, mn, me = _batch_plan_sub(sub, cfg.batch_graphs)
        packed = []
        for b in batches:
            feats, edges = M.pad_graph_batch([sub[i] for i in b], mn, me, ds.num_features)
            tgt = np.asarray([ds.targets[ids[i]] for i in b])
            gmask = np.zeros(len(b), dtype=np.float32) + 1.0
            packed.append((jnp.asarray(feats), edges, jnp.asarray(tgt), len(b)))
        return packed

    def _batch_plan_sub(graphs, bs):
        order = np.arange(len(graphs))
        batches = [order[i : i + bs] for i in range(0, len(graphs), bs)]
        mn = max(sum(graphs[i].num_nodes for i in b) for b in batches)
        me = max(
            sum(graphs[i].indices.shape[0] + graphs[i].num_nodes for i in b)
            for b in batches
        )
        return batches, mn, me

    train_batches = pack(tr_ids)
    val_batches = pack(va_ids)
    test_batches = pack(te_ids)

    # NNS bits penalty: groups are [m]; dims use hidden size per layer
    bits_list, dim_list = M.feature_bits_and_dims(qp, mcfg)
    m_target = mem_target_kb(cfg, dim_list, [cfg.nns_m] * len(dim_list))

    tree = {"model": params, "qp": qp}
    if train_batches:
        cal_x, cal_edges, _, _ = train_batches[0]
        tree = calibrate_qparams(tree, mcfg, qcfg, cal_x, cal_edges, cfg)
    opt = adam_init(tree)
    lr_tree = lr_tree_for(tree, cfg.lr, cfg.lr_step, cfg.lr_bits)

    def loss_fn(tree, feats, edges, tgt, nb, lam):
        out, _ = M.forward(tree["model"], tree["qp"], feats, edges, mcfg, qcfg, train=False)
        out = out[:nb]
        if regression:
            task = jnp.mean(jnp.abs(out[:, 0] - tgt))
        else:
            logp = jax.nn.log_softmax(out)
            task = -jnp.mean(logp[jnp.arange(nb), tgt.astype(jnp.int32)])
        loss = task
        if tree["qp"] and "feat" in tree["qp"] and cfg.method in ("a2q", "a2q_global") and qcfg.learn_bits:
            bl, dl = M.feature_bits_and_dims(tree["qp"], mcfg)
            loss = loss + lam * Q.memory_penalty(bl, dl, m_target)
        return loss, task

    nb_static = train_batches[0][3]

    @jax.jit
    def step(tree, opt, feats, edges, tgt, lam):
        (loss, task), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            tree, feats, edges, tgt, nb_static, lam
        )
        tree, opt = adam_update(tree, grads, opt, lr_tree)
        tree = {"model": tree["model"], "qp": clamp_qparams(tree["qp"])}
        return tree, opt, task

    # NOTE: batches in one split share shapes; the last ragged batch is
    # dropped from training (kept for eval via per-batch jit cache).
    def run_epoch(tree, opt, lam):
        tot = 0.0
        cnt = 0
        for feats, edges, tgt, nb in train_batches:
            if nb != nb_static:
                continue
            tree, opt, task = step(tree, opt, feats, edges, tgt, lam)
            tot += float(task)
            cnt += 1
        return tree, opt, tot / max(cnt, 1)

    @jax.jit
    def eval_batch(tree, feats, edges):
        out, _ = M.forward(
            tree["model"], tree["qp"], feats, edges, mcfg, qcfg, train=False
        )
        return out

    def eval_split(tree, batches):
        """Accuracy (classification) or MAE (regression) over a split."""
        good, tot, err = 0.0, 0, 0.0
        for feats, edges, tgt, nb in batches:
            out = eval_batch(tree, feats, edges)[:nb]
            if regression:
                err += float(jnp.sum(jnp.abs(out[:, 0] - tgt)))
            else:
                good += float(jnp.sum((jnp.argmax(out, -1) == tgt.astype(jnp.int32))))
            tot += nb
        return (err / tot) if regression else (good / tot)
    history = []
    best_val = np.inf if regression else -np.inf
    best_test = 0.0
    for epoch in range(cfg.epochs):
        lam = jnp.asarray(cfg.lam if epoch >= cfg.penalty_warmup else 0.0)
        tree, opt, tr_loss = run_epoch(tree, opt, lam)
        if epoch % 5 == 0 or epoch == cfg.epochs - 1:
            va = eval_split(tree, val_batches)
            te = eval_split(tree, test_batches)
            history.append((epoch, tr_loss, va))
            better = va <= best_val if regression else va >= best_val
            if better:
                best_val, best_test = va, te

    avg_bits = (
        effective_avg_bits(tree["qp"], mcfg, qcfg)
        if cfg.method in ("a2q", "a2q_global", "manual")
        else {"fp32": 32.0, "dq": 4.0, "binary": 1.0}.get(cfg.method, 4.0)
    )
    result = TrainResult(
        config=asdict(cfg),
        accuracy=float(best_test) if not regression else -float(best_test),
        metric_name="mae" if regression else "accuracy",
        avg_bits=avg_bits,
        compression=32.0 / avg_bits,
        train_seconds=time.time() - t0,
        epochs_run=cfg.epochs,
        history=history,
        bits_hist=bits_histogram(tree["qp"]),
    )
    blob = asdict(result)
    hit, path = cached(cfg)
    save_tree(tree, tree_path(cfg))
    with open(path, "w") as fh:
        json.dump(blob, fh)
    return blob, path


def train_any(cfg: TrainConfig, use_cache: bool = True):
    if cfg.dataset in D.NODE_SPECS:
        return train_node(cfg, use_cache)
    return train_graph(cfg, use_cache)


def rebuild_tree(cfg: TrainConfig):
    """Reconstruct (tree, mcfg, qcfg) for a trained config from its .npz."""
    if cfg.dataset in D.NODE_SPECS:
        ds = D.make_node_dataset(cfg.dataset, seed=0)
        n, out_dim, readout, binary = (
            ds.num_nodes,
            ds.num_classes,
            "none",
            ds.binary_features,
        )
        in_dim = ds.num_features
        graph_level = False
    else:
        ds = D.make_graph_dataset(cfg.dataset, seed=0)
        out_dim = 1 if ds.num_classes == 0 else ds.num_classes
        n, readout, binary = cfg.nns_m, "mean", False
        in_dim = ds.num_features
        graph_level = True
    mcfg = M.ModelConfig(
        arch=cfg.arch, in_dim=in_dim, hidden=cfg.hidden, out_dim=out_dim,
        layers=cfg.layers, heads=cfg.heads, skip=cfg.skip,
        dropout=cfg.dropout if not graph_level else 0.0, readout=readout,
    )
    qcfg = build_qcfg(cfg, binary, graph_level)
    rng = jax.random.PRNGKey(cfg.seed)
    _, k1, k2 = jax.random.split(rng, 3)
    template = {
        "model": M.init_params(k1, mcfg),
        "qp": M.init_qparams(k2, mcfg, qcfg, n),
    }
    tree = load_tree(template, tree_path(cfg))
    return tree, mcfg, qcfg, ds
