"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact functional twin here, written
with plain ``jax.numpy`` ops only.  ``python/tests/test_kernels.py`` sweeps
shapes/dtypes with hypothesis and asserts ``assert_allclose`` between the two.

The quantizer follows Eq. 1 / Eq. 9 of the paper:

    xbar = sign(x) * min( floor(|x|/s + 0.5), 2^(b-1) - 1 )
    x_q  = s * xbar

with the unsigned variant (features after ReLU, paper §3.1: "[b]+1 bits")
using ``2^b - 1`` positive levels and no sign bit.
"""

from __future__ import annotations

import jax.numpy as jnp


def quant_levels(bits: jnp.ndarray, signed: bool) -> jnp.ndarray:
    """Number of positive quantization levels for an (integer-valued) bitwidth.

    Signed symmetric uniform quantization keeps one bit for the sign:
    ``2^(b-1) - 1``.  Unsigned (post-ReLU) uses all bits: ``2^b - 1``.
    """
    b = jnp.round(bits)
    if signed:
        return jnp.exp2(b - 1.0) - 1.0
    return jnp.exp2(b) - 1.0


def quantize_ref(
    x: jnp.ndarray,
    step: jnp.ndarray,
    bits: jnp.ndarray,
    *,
    signed: bool = True,
) -> jnp.ndarray:
    """Fake-quantize ``x`` with per-row step/bits (Eq. 1).

    ``step`` and ``bits`` broadcast against ``x`` rows: for ``x`` of shape
    ``[N, F]`` they are ``[N]`` (per-node, aggregation-aware) or scalars.
    Returns the dequantized representation ``x_q = s * xbar``.
    """
    step = jnp.asarray(step)
    bits = jnp.asarray(bits)
    if step.ndim == 1:
        step = step[:, None]
    if bits.ndim == 1:
        bits = bits[:, None]
    step = jnp.maximum(step, 1e-9)
    levels = quant_levels(bits, signed)
    mag = jnp.floor(jnp.abs(x) / step + 0.5)
    mag = jnp.minimum(mag, levels)
    xbar = jnp.sign(x) * mag
    if not signed:
        xbar = jnp.maximum(xbar, 0.0)
    return step * xbar


def quantize_int_ref(
    x: jnp.ndarray,
    step: jnp.ndarray,
    bits: jnp.ndarray,
    *,
    signed: bool = True,
) -> jnp.ndarray:
    """Integer codes ``xbar`` (as f32) rather than the dequantized value."""
    q = quantize_ref(x, step, bits, signed=signed)
    step = jnp.asarray(step)
    if step.ndim == 1:
        step = step[:, None]
    return q / jnp.maximum(step, 1e-9)


def qmatmul_ref(
    xbar: jnp.ndarray,
    wbar: jnp.ndarray,
    sx: jnp.ndarray,
    sw: jnp.ndarray,
) -> jnp.ndarray:
    """Integer-domain matmul with outer-product rescale (Eq. 2).

    ``xbar``: [M, K] integer-valued activations, per-row scales ``sx`` [M].
    ``wbar``: [K, N] integer-valued weights, per-column scales ``sw`` [N].
    Result: ``(xbar @ wbar) ⊙ (sx ⊗ sw)`` — exactly Eq. 2 of the paper.
    """
    acc = jnp.matmul(xbar, wbar, preferred_element_type=jnp.float32)
    return acc * (sx[:, None] * sw[None, :])


def nns_select_ref(
    x: jnp.ndarray,
    step_g: jnp.ndarray,
    bits_g: jnp.ndarray,
    *,
    signed: bool = True,
):
    """Nearest Neighbor Strategy (Algorithm 1) reference.

    For each node (row of ``x``): find the group ``g`` minimising
    ``| max_j |x_ij|  -  q_max^g |`` where ``q_max^g = s_g (2^{b_g-1}-1)``,
    then return (index, step, bits) per node.  Ties break toward the lower
    index, matching ``jnp.argmin`` semantics (and the rust implementation).
    """
    levels = quant_levels(bits_g, signed)
    qmax = step_g * levels  # [m]
    f = jnp.max(jnp.abs(x), axis=-1)  # [N]
    dist = jnp.abs(f[:, None] - qmax[None, :])  # [N, m]
    idx = jnp.argmin(dist, axis=-1)
    return idx, step_g[idx], bits_g[idx]


def nns_quantize_ref(
    x: jnp.ndarray,
    step_g: jnp.ndarray,
    bits_g: jnp.ndarray,
    *,
    signed: bool = True,
) -> jnp.ndarray:
    """Full NNS pipeline: select a group per node, then fake-quantize."""
    _, s, b = nns_select_ref(x, step_g, bits_g, signed=signed)
    return quantize_ref(x, s, b, signed=signed)


def csr_aggregate_ref(
    x: jnp.ndarray,
    edge_src: jnp.ndarray,
    edge_dst: jnp.ndarray,
    edge_w: jnp.ndarray,
    num_nodes: int,
) -> jnp.ndarray:
    """Message-passing aggregation  out[d] += w_e * x[s]  (sum aggregator)."""
    msgs = x[edge_src] * edge_w[:, None]
    return jnp.zeros((num_nodes, x.shape[1]), x.dtype).at[edge_dst].add(msgs)
