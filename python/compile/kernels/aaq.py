"""Pallas kernel: Aggregation-Aware fake-quantization (Eq. 1), per-node (s, b).

This is the L1 hot-spot of the A²Q inference path: every layer quantizes the
[N, F] node-feature matrix with a *per-row* learnable step size and bitwidth.

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel tiles rows into
(BLOCK_N, F) VMEM blocks — the per-node scalars (s, b) ride along as a
(BLOCK_N,) vector per tile.  The op is purely element-wise over lanes so it
targets the VPU, not the MXU; the block shape is chosen to keep the
HBM↔VMEM schedule streaming (one pass over X) with 8×128-aligned tiles.

Run with ``interpret=True`` everywhere in this repo: the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step.  8-sublane aligned; at F=4096 lanes this is
# 128*4096*4B = 2 MiB of VMEM for the input block, well inside the ~16 MiB
# budget together with the output block.
DEFAULT_BLOCK_N = 128


def _aaq_kernel(x_ref, s_ref, b_ref, o_ref, *, signed: bool):
    """One (BLOCK_N, F) tile: xq = s * clip(round(|x|/s), 0, levels) * sign."""
    x = x_ref[...]
    s = jnp.maximum(s_ref[...], 1e-9)[:, None]
    b = jnp.round(b_ref[...])[:, None]
    levels = (jnp.exp2(b - 1.0) - 1.0) if signed else (jnp.exp2(b) - 1.0)
    mag = jnp.floor(jnp.abs(x) / s + 0.5)
    mag = jnp.minimum(mag, levels)
    xbar = jnp.sign(x) * mag
    if not signed:
        xbar = jnp.maximum(xbar, 0.0)
    o_ref[...] = s * xbar


@functools.partial(jax.jit, static_argnames=("signed", "block_n"))
def aaq_quantize(
    x: jnp.ndarray,
    step: jnp.ndarray,
    bits: jnp.ndarray,
    *,
    signed: bool = True,
    block_n: int = DEFAULT_BLOCK_N,
) -> jnp.ndarray:
    """Fake-quantize ``x`` [N, F] with per-row ``step``/``bits`` [N].

    Matches ``ref.quantize_ref`` exactly (pytest/hypothesis enforced).
    Rows are padded up to a multiple of ``block_n``; padding rows use
    step=1, bits=8 and are sliced off afterwards.
    """
    n, f = x.shape
    n_pad = (-n) % block_n
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
        step = jnp.pad(step, (0, n_pad), constant_values=1.0)
        bits = jnp.pad(bits, (0, n_pad), constant_values=8.0)
    grid = ((n + n_pad) // block_n,)
    out = pl.pallas_call(
        functools.partial(_aaq_kernel, signed=signed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, step, bits)
    return out[:n] if n_pad else out


def vmem_bytes(block_n: int, f: int) -> int:
    """Estimated VMEM working set of one grid step (input+output+scalars)."""
    return 2 * block_n * f * 4 + 2 * block_n * 4
