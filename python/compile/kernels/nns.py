"""Pallas kernel: Nearest Neighbor Strategy selection + quantize (Algorithm 1).

Graph-level tasks see unseen graphs with varying node counts, so A²Q learns a
fixed pool of ``m`` (step, bits) groups and each node picks the group whose
``q_max = s·(2^{b-1}-1)`` is nearest to the node's max-|feature|.

The paper implements the search with a sorted-q_max binary search plus a
comparator array in hardware.  A TPU has no scalar branching worth using
inside a vectorised kernel, so the kernel does the branchless equivalent:
a (BLOCK_N, m) broadcast compare + argmin, which is exactly the comparator
array unrolled over lanes.  m ≈ 1000 keeps the (BLOCK_N, m) distance tile at
128×1024×4B = 512 KiB — fine for VMEM.

The rust serving path (``quant::nns``) uses the true binary search on sorted
q_max; ``python/tests`` pins both to ``ref.nns_select_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128


def _nns_kernel(x_ref, qmax_ref, s_ref, b_ref, o_ref, idx_ref, *, signed: bool):
    x = x_ref[...]  # (BN, F)
    qmax = qmax_ref[...]  # (m,)
    f = jnp.max(jnp.abs(x), axis=-1)  # (BN,)
    dist = jnp.abs(f[:, None] - qmax[None, :])  # (BN, m)
    idx = jnp.argmin(dist, axis=-1)  # (BN,)
    s = jnp.maximum(s_ref[...][idx], 1e-9)[:, None]
    b = jnp.round(b_ref[...][idx])[:, None]
    levels = (jnp.exp2(b - 1.0) - 1.0) if signed else (jnp.exp2(b) - 1.0)
    mag = jnp.minimum(jnp.floor(jnp.abs(x) / s + 0.5), levels)
    xbar = jnp.sign(x) * mag
    if not signed:
        xbar = jnp.maximum(xbar, 0.0)
    o_ref[...] = s * xbar
    idx_ref[...] = idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("signed", "block_n"))
def nns_quantize(
    x: jnp.ndarray,
    step_g: jnp.ndarray,
    bits_g: jnp.ndarray,
    *,
    signed: bool = True,
    block_n: int = DEFAULT_BLOCK_N,
):
    """NNS select + fake-quantize.  Returns ``(x_q, index)``.

    ``x`` [N, F]; ``step_g``/``bits_g`` [m] learned group parameters.
    Matches ``ref.nns_quantize_ref`` / ``ref.nns_select_ref``.
    """
    n, f = x.shape
    m = step_g.shape[0]
    levels = (
        jnp.exp2(jnp.round(bits_g) - 1.0) - 1.0
        if signed
        else jnp.exp2(jnp.round(bits_g)) - 1.0
    )
    qmax = step_g * levels
    n_pad = (-n) % block_n
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    grid = ((n + n_pad) // block_n,)
    xq, idx = pl.pallas_call(
        functools.partial(_nns_kernel, signed=signed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
        ],
        interpret=True,
    )(x, qmax, step_g, bits_g)
    if n_pad:
        xq, idx = xq[:n], idx[:n]
    return xq, idx


def vmem_bytes(block_n: int, f: int, m: int) -> int:
    """Per-step VMEM: x + out tiles, (BN, m) distance tile, 3 m-vectors."""
    return 2 * block_n * f * 4 + block_n * m * 4 + 3 * m * 4
