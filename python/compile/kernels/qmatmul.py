"""Pallas kernel: integer-domain matmul with outer-product rescale (Eq. 2).

The A²Q update phase computes  X·W ≈ (X̄·W̄) ⊙ (s_X ⊗ s_W)  where X̄ holds the
per-node integer codes and W̄ the per-column integer codes.  On real TPU
hardware the integer codes live in bf16/int8 and hit the MXU systolic array;
here the codes are integer-valued f32 (interpret mode), so the kernel
structure — (BM, BK)×(BK, BN) tiles, K-innermost accumulation in VMEM
scratch, rescale fused into the final store — is what we validate, and the
MXU utilization is *estimated* in EXPERIMENTS.md §Perf from the tile shapes.

Tiles default to 128×128×128: MXU-native (128×128) and 3 blocks × 64 KiB
per step, comfortably double-bufferable in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BK = 128
DEFAULT_BN = 128


def _qmm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *, k_steps: int):
    """Grid (M/BM, N/BN, K/BK); K is the innermost (fastest) axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _store():
        # Fused Eq. 2 rescale: one multiply per output element, no extra
        # HBM round-trip for the integer accumulator.
        o_ref[...] = acc_ref[...] * (sx_ref[...][:, None] * sw_ref[...][None, :])


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def qmatmul(
    xbar: jnp.ndarray,
    wbar: jnp.ndarray,
    sx: jnp.ndarray,
    sw: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
) -> jnp.ndarray:
    """Quantized matmul: ``(xbar @ wbar) * outer(sx, sw)``.

    ``xbar`` [M, K] integer-valued codes with per-row scales ``sx`` [M];
    ``wbar`` [K, N] integer-valued codes with per-column scales ``sw`` [N].
    Matches ``ref.qmatmul_ref``.
    """
    m, k = xbar.shape
    k2, n = wbar.shape
    assert k == k2, (xbar.shape, wbar.shape)
    mp, kp, np_ = (-m) % bm, (-k) % bk, (-n) % bn
    if mp or kp:
        xbar = jnp.pad(xbar, ((0, mp), (0, kp)))
        sx = jnp.pad(sx, (0, mp))
    if kp or np_:
        wbar = jnp.pad(wbar, ((0, kp), (0, np_)))
        sw = jnp.pad(sw, (0, np_))
    gm, gn, gk = (m + mp) // bm, (n + np_) // bn, (k + kp) // bk
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, k_steps=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + mp, n + np_), jnp.float32),
        scratch_shapes=[pltpu_scratch(bm, bn)],
        interpret=True,
    )(xbar, wbar, sx, sw)
    return out[:m, :n]


def pltpu_scratch(bm: int, bn: int):
    """VMEM accumulator scratch, version-portable."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM((bm, bn), jnp.float32)
    except Exception:  # pragma: no cover - fallback for older jax
        return pl.BlockSpec.memory_space  # type: ignore[attr-defined]


def flops(m: int, k: int, n: int) -> int:
    """MAC count ×2 for the tile-level roofline estimate."""
    return 2 * m * k * n


def vmem_bytes(bm: int, bk: int, bn: int) -> int:
    """Per-step VMEM working set: x, w, acc, out tiles + scale vectors."""
    return (bm * bk + bk * bn + 2 * bm * bn) * 4 + (bm + bn) * 4
