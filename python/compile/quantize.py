"""A²Q quantization machinery (L2, build-time): learnable (step, bits) per node.

Implements the paper's training-time components:

* ``a2q_quantize`` — Eq. 1 fake-quant with a custom VJP implementing the
  closed-form STE gradients of Eq. 10, in two flavours:
  - ``grad_mode="global"``: task-loss gradients (Eq. 3/4),
  - ``grad_mode="local"``:  Local Gradient (§3.2, Eq. 7/8) — the incoming
    task cotangent is *replaced* for (s, b) by the gradient of the local
    quantization error E = (1/d)·|x_q − x|₁, fixing the vanishing-gradient
    problem of semi-supervised node tasks (Proof 1).
* ``nns_quantize_train`` — Nearest Neighbor Strategy (Algorithm 1) with a
  straight-through argmin: gradients scatter-add into the selected groups.
* ``memory_penalty`` — Eq. 5 memory-size loss on the learned bitwidths.
* Baselines: ``dq_quantize`` (Degree-Quant, INT4), ``binary_quantize``
  (Bi-GCN-style sign), ``manual`` bit assignment (ablation, Fig. 5).

All quantizers are pure functions over (x, params) so the same model code
runs FP32 / A²Q / DQ / binary by swapping the feature-quantizer closure.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

LN2 = 0.6931471805599453
MIN_STEP = 1e-9
# Learnable-bitwidth clamp. The paper reports learned bits in [1, 8]; the
# round() in Eq. 1 needs b >= 1 to be meaningful and >8 never helps vs FP32.
BITS_LO, BITS_HI = 1.0, 8.0


def _levels(bits_round: jnp.ndarray, signed: bool) -> jnp.ndarray:
    return jnp.exp2(bits_round - 1.0) - 1.0 if signed else jnp.exp2(bits_round) - 1.0


def _fake_quant(x, step, bits, signed):
    """Eq. 1 forward. step/bits already broadcast to x's rows ([N] vs [N,F])."""
    s = jnp.maximum(step, MIN_STEP)[:, None]
    br = jnp.round(jnp.clip(bits, BITS_LO, BITS_HI))[:, None]
    lv = _levels(br, signed)
    mag = jnp.minimum(jnp.floor(jnp.abs(x) / s + 0.5), lv)
    xbar = jnp.sign(x) * mag
    if not signed:
        xbar = jnp.maximum(xbar, 0.0)
    return s * xbar


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def a2q_quantize(x, step, bits, signed: bool = True, grad_mode: str = "global"):
    """Aggregation-aware fake-quant with learnable per-row (step, bits).

    ``x`` [N, F]; ``step``/``bits`` [N].  ``grad_mode`` picks Eq. 3/4
    ("global") or Eq. 7/8 ("local") for the (step, bits) gradients.
    """
    return _fake_quant(x, step, bits, signed)


def _a2q_fwd(x, step, bits, signed, grad_mode):
    xq = _fake_quant(x, step, bits, signed)
    return xq, (x, step, bits, xq)


def _a2q_bwd(signed, grad_mode, res, g):
    x, step, bits, xq = res
    s = jnp.maximum(step, MIN_STEP)[:, None]
    br = jnp.round(jnp.clip(bits, BITS_LO, BITS_HI))[:, None]
    lv = _levels(br, signed)
    in_range = jnp.abs(x) < s * lv
    # Eq. 10: closed-form partials through the STE.
    dxq_ds = jnp.where(in_range, (xq - x) / s, jnp.sign(x) * lv)
    pow_term = jnp.exp2(br - 1.0) if signed else jnp.exp2(br)
    dxq_db = jnp.where(in_range, 0.0, jnp.sign(x) * pow_term * LN2 * s)
    if not signed:
        neg = x < 0.0
        dxq_ds = jnp.where(neg, 0.0, dxq_ds)
        dxq_db = jnp.where(neg, 0.0, dxq_db)

    g_x = g * in_range.astype(g.dtype)  # STE indicator (App. A.1.2)

    if grad_mode == "local":
        # Local Gradient (Eq. 7/8): supervision is the quantization error
        # E = (1/d)|x_q - x|_1, independent of the (possibly zero) task
        # cotangent g.
        d = x.shape[-1]
        e = jnp.sign(xq - x) / d
        g_s = jnp.sum(e * dxq_ds, axis=-1)
        g_b = jnp.sum(e * dxq_db, axis=-1)
    else:
        g_s = jnp.sum(g * dxq_ds, axis=-1)
        g_b = jnp.sum(g * dxq_db, axis=-1)
    return g_x, g_s, g_b


a2q_quantize.defvjp(_a2q_fwd, _a2q_bwd)


def quantize_weights(w, step, bits: float = 4.0):
    """Per-output-column weight fake-quant (paper fixes W to 4 bits).

    ``w`` [F_in, F_out], ``step`` [F_out] learnable (trained with the global
    gradient — weights always receive task gradients).
    """
    wq_t = a2q_quantize(w.T, step, jnp.full_like(step, bits), True, "global")
    return wq_t.T


def weight_codes(w, step, bits: float = 4.0):
    """Integer codes + scales for export: w ≈ codes * step (per column)."""
    wq = quantize_weights(w, step, bits)
    return wq / jnp.maximum(step, MIN_STEP)[None, :], step


# ---------------------------------------------------------------------------
# Nearest Neighbor Strategy (Algorithm 1)
# ---------------------------------------------------------------------------


def nns_quantize_train(x, step_g, bits_g, signed: bool = True):
    """NNS forward with trainable groups.

    The argmin index is non-differentiable (stop-gradient); the gathered
    (s, b) remain differentiable, so backprop scatter-adds each node's
    gradient into its selected group — exactly the paper's "collect the
    gradients from the nodes that have used them and add these together".
    """
    br = jnp.round(jnp.clip(bits_g, BITS_LO, BITS_HI))
    qmax = jnp.maximum(step_g, MIN_STEP) * _levels(br, signed)
    f = jnp.max(jnp.abs(x), axis=-1)
    idx = jnp.argmin(jnp.abs(f[:, None] - qmax[None, :]), axis=-1)
    idx = jax.lax.stop_gradient(idx)
    s_i = step_g[idx]
    b_i = bits_g[idx]
    return a2q_quantize(x, s_i, b_i, signed, "global"), idx


# ---------------------------------------------------------------------------
# Memory penalty (Eq. 5/6)
# ---------------------------------------------------------------------------


def memory_penalty(bits_per_layer, dims, target_kb: float) -> jnp.ndarray:
    """L_mem = (1/η · Σ_l Σ_i dim_l · b_i^l  −  M_target)²  with η = 8·1024.

    ``bits_per_layer``: list of [N]-arrays of learnable bits (one per
    quantized feature map), ``dims``: matching feature dimensions.
    ``target_kb``: M_target in KB.
    """
    eta = 8.0 * 1024.0
    total = 0.0
    for b, dim in zip(bits_per_layer, dims):
        total = total + jnp.sum(jnp.clip(b, BITS_LO, BITS_HI)) * float(dim)
    return (total / eta - target_kb) ** 2


def average_bits(bits_per_layer, dims) -> jnp.ndarray:
    """Feature-memory-weighted average bitwidth (the paper's "Average bits")."""
    num = 0.0
    den = 0.0
    for b, dim in zip(bits_per_layer, dims):
        br = jnp.round(jnp.clip(b, BITS_LO, BITS_HI))
        num = num + jnp.sum(br) * float(dim)
        den = den + b.shape[0] * float(dim)
    return num / den


def compression_ratio(avg_bits: float) -> float:
    """FP32 feature memory / quantized feature memory."""
    return 32.0 / float(avg_bits)


# ---------------------------------------------------------------------------
# Baseline quantizers
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def lsq_quantize(x, step, bits: float = 4.0, signed: bool = True):
    """LSQ-style per-tensor fake-quant with a learnable scalar step (DQ uses
    this form for INT4).  Gradient for step follows Esser et al. (2019)."""
    s = jnp.maximum(step, MIN_STEP)
    lv = _levels(jnp.round(jnp.asarray(bits)), signed)
    mag = jnp.minimum(jnp.floor(jnp.abs(x) / s + 0.5), lv)
    xbar = jnp.sign(x) * mag
    if not signed:
        xbar = jnp.maximum(xbar, 0.0)
    return s * xbar


def _lsq_fwd(x, step, bits, signed):
    return lsq_quantize(x, step, bits, signed), (x, step)


def _lsq_bwd(bits, signed, res, g):
    x, step = res
    s = jnp.maximum(step, MIN_STEP)
    lv = _levels(jnp.round(jnp.asarray(bits)), signed)
    xq = lsq_quantize(x, step, bits, signed)
    in_range = jnp.abs(x) < s * lv
    g_x = g * in_range.astype(g.dtype)
    dxq_ds = jnp.where(in_range, (xq - x) / s, jnp.sign(x) * lv)
    if not signed:
        dxq_ds = jnp.where(x < 0.0, 0.0, dxq_ds)
    # LSQ gradient-scale 1/sqrt(N * levels) stabilises the scalar step.
    gscale = 1.0 / jnp.sqrt(float(x.size) * jnp.maximum(lv, 1.0))
    g_s = jnp.sum(g * dxq_ds) * gscale
    return g_x, g_s


lsq_quantize.defvjp(_lsq_fwd, _lsq_bwd)


def dq_quantize(x, step, prot_mask, bits: float = 4.0, signed: bool = True):
    """Degree-Quant (Tailor et al., 2020) feature quantization, simplified.

    High in-degree nodes are stochastically "protected" (operate FP32)
    during training via ``prot_mask`` [N] ∈ {0,1}; at inference the mask is
    all-zero and everything is INT4.  Per-tensor learnable step (LSQ).
    """
    xq = lsq_quantize(x, step, bits, signed)
    keep = prot_mask[:, None].astype(x.dtype)
    return keep * x + (1.0 - keep) * xq


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _sign_ste(x):
    return jnp.sign(x)


def _sign_fwd(x):
    return jnp.sign(x), x


def _sign_bwd(res, g):
    x = res
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


_sign_ste.defvjp(_sign_fwd, _sign_bwd)


def binary_quantize(x):
    """Bi-GCN-style 1-bit: sign(x) scaled by the per-row mean |x|."""
    alpha = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)
    return _sign_ste(x) * jax.lax.stop_gradient(alpha)


def manual_bits_by_degree(in_degree, avg_bits: float, hi_frac: float = 0.2):
    """Manual mixed-precision baseline (Fig. 5): top ``hi_frac`` in-degree
    nodes get ``ceil(avg)+…`` high bits, rest low bits, matching the paper's
    A.6.1 recipe (e.g. avg 2.2 → top 20% at 3 bits, others at 2 bits)."""
    import numpy as np

    n = in_degree.shape[0]
    lo = int(np.floor(avg_bits))
    hi = lo + 1
    # choose the high fraction so that the average matches avg_bits
    frac_hi = float(avg_bits - lo)
    k = int(round(frac_hi * n))
    order = np.argsort(-np.asarray(in_degree), kind="stable")
    bits = np.full(n, lo, dtype=np.float32)
    bits[order[:k]] = hi
    return jnp.asarray(bits)


# ---------------------------------------------------------------------------
# Quantizer parameter initialisation
# ---------------------------------------------------------------------------


class QuantInit(NamedTuple):
    step: jnp.ndarray
    bits: jnp.ndarray


def init_feature_qparams(rng, n: int, init_bits: float = 4.0) -> QuantInit:
    """Paper A.6: bits init 4, step ~ N(0.01, 0.01) (clamped positive)."""
    s = 0.01 + 0.01 * jax.random.normal(rng, (n,))
    return QuantInit(jnp.maximum(s, 1e-3), jnp.full((n,), init_bits))


def init_weight_steps(rng, f_out: int) -> jnp.ndarray:
    s = 0.01 + 0.01 * jax.random.normal(rng, (f_out,))
    return jnp.maximum(s, 1e-3)
