"""Synthetic dataset generators standing in for the paper's benchmarks.

Repro band = 0: Planetoid/OGB/TU/superpixel/ZINC downloads are unavailable in
this environment, so we build synthetic analogues that preserve exactly the
graph properties A²Q's mechanism depends on (DESIGN.md §3):

1. **power-law in-degree** (preferential attachment) — drives Fig. 1/8 and
   the "most nodes are low-bit" compression argument;
2. **degree ↔ aggregated-feature-magnitude correlation** — the core
   aggregation-aware observation;
3. **tiny labeled fraction** for node-level semi-supervised tasks — drives
   the Local Gradient motivation (Proof 1);
4. **variable node counts** across graphs for graph-level tasks — drives the
   Nearest Neighbor Strategy.

Node/feature/class counts and label rates mirror Table 7 (ogbn-arxiv and
PubMed analogues are scaled down for the single-core CI budget; scaling
factors documented here and in EXPERIMENTS.md).

Every dataset serialises to ``artifacts/data/<name>.bin`` in a little-endian
binary format shared with the rust loader (``rust/src/graph/io.rs``):

    magic  "A2QD" | version u32 | kind u32 (0 node-level, 1 graph-level)
    node-level:  N u32 | F u32 | C u32 | nnz u32
                 indptr  u32[N+1]   (CSR over *incoming* edges, dst-major)
                 indices u32[nnz]   (source node of each incoming edge)
                 feat    f32[N*F]
                 labels  i32[N]
                 train/val/test masks u8[N] each
    graph-level: G u32 | F u32 | C u32 (0 ⇒ regression) then per graph:
                 N u32 | nnz u32 | indptr u32[N+1] | indices u32[nnz]
                 feat f32[N*F] | target (i32 label, or f32 if regression)
"""

from __future__ import annotations

import os
import struct
import zlib
from dataclasses import dataclass

import numpy as np

MAGIC = b"A2QD"
VERSION = 1


def _stable_hash(name: str) -> int:
    """Process-independent name hash (python's ``hash()`` is randomized per
    interpreter, which would give every process a different graph)."""
    return zlib.crc32(name.encode()) & 0xFFFF


# ---------------------------------------------------------------------------
# Containers
# ---------------------------------------------------------------------------


@dataclass
class NodeDataset:
    """A single graph with node labels and semi-supervised splits."""

    name: str
    indptr: np.ndarray  # [N+1] u32, CSR over incoming edges
    indices: np.ndarray  # [nnz] u32 source ids
    features: np.ndarray  # [N, F] f32
    labels: np.ndarray  # [N] i32
    train_mask: np.ndarray  # [N] bool
    val_mask: np.ndarray
    test_mask: np.ndarray
    num_classes: int
    binary_features: bool = False  # bag-of-words 0/1 (skip input quant)

    @property
    def num_nodes(self) -> int:
        return self.features.shape[0]

    @property
    def num_features(self) -> int:
        return self.features.shape[1]

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def in_degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def edge_list(self):
        """(src, dst) arrays; dst-major order matching the CSR."""
        dst = np.repeat(np.arange(self.num_nodes, dtype=np.int64), self.in_degrees())
        return self.indices.astype(np.int64), dst


@dataclass
class GraphDataset:
    """A set of small graphs with per-graph targets (classif or regression)."""

    name: str
    graphs: list  # list[NodeDataset-like tuples]
    num_features: int
    num_classes: int  # 0 => regression
    targets: np.ndarray  # [G] i64 labels or f32 regression targets

    @property
    def num_graphs(self) -> int:
        return len(self.graphs)


@dataclass
class SmallGraph:
    indptr: np.ndarray
    indices: np.ndarray
    features: np.ndarray

    @property
    def num_nodes(self) -> int:
        return self.features.shape[0]

    def in_degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def edge_list(self):
        dst = np.repeat(np.arange(self.num_nodes, dtype=np.int64), self.in_degrees())
        return self.indices.astype(np.int64), dst


# ---------------------------------------------------------------------------
# Graph construction helpers
# ---------------------------------------------------------------------------


def _edges_to_csr(n: int, src: np.ndarray, dst: np.ndarray):
    """Build an incoming-edge CSR (dst-major), deduplicated."""
    key = dst.astype(np.int64) * n + src.astype(np.int64)
    key = np.unique(key)
    dst_u = (key // n).astype(np.int64)
    src_u = (key % n).astype(np.int64)
    counts = np.bincount(dst_u, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.uint32)
    np.cumsum(counts, out=indptr[1:])
    return indptr, src_u.astype(np.uint32)


def _preferential_attachment(
    rng: np.random.Generator,
    n: int,
    m: int,
    labels: np.ndarray | None = None,
    assort: float = 0.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Barabási–Albert-style undirected generator with optional class
    assortativity: with probability ``assort`` the preferential choice is
    restricted to same-class nodes (citation networks are homophilous)."""
    src_l: list[int] = []
    dst_l: list[int] = []
    # start with a small clique
    seed_n = max(m + 1, 3)
    for i in range(seed_n):
        for j in range(i):
            src_l.append(i)
            dst_l.append(j)
    # repeated-endpoint trick gives preferential attachment in O(E)
    endpoints = list(src_l) + list(dst_l)
    for v in range(seed_n, n):
        targets: set[int] = set()
        attempts = 0
        while len(targets) < m and attempts < 50 * m:
            attempts += 1
            u = endpoints[rng.integers(len(endpoints))]
            if labels is not None and assort > 0.0 and rng.random() < assort:
                if labels[u] != labels[v]:
                    continue
            if u != v:
                targets.add(int(u))
        for u in targets:
            src_l.append(v)
            dst_l.append(u)
            endpoints.extend((v, u))
    src = np.asarray(src_l, dtype=np.int64)
    dst = np.asarray(dst_l, dtype=np.int64)
    # undirected: both directions
    return np.concatenate([src, dst]), np.concatenate([dst, src])


def _bow_features(
    rng: np.random.Generator, labels: np.ndarray, f: int, c: int, active: int
) -> np.ndarray:
    """Binary bag-of-words features with class-specific vocabularies,
    mimicking Planetoid citation features (values ∈ {0,1})."""
    n = labels.shape[0]
    words_per_class = f // c
    feats = np.zeros((n, f), dtype=np.float32)
    for i in range(n):
        cls = labels[i]
        vocab_lo = cls * words_per_class
        k_sig = max(1, int(active * 0.7))
        sig = vocab_lo + rng.integers(0, words_per_class, size=k_sig)
        noise = rng.integers(0, f, size=active - k_sig)
        feats[i, sig] = 1.0
        feats[i, noise] = 1.0
    return feats


def _splits(
    rng: np.random.Generator, n: int, train_frac: float, val_frac: float = 0.15
):
    order = rng.permutation(n)
    n_tr = max(int(round(train_frac * n)), 4)
    n_va = int(val_frac * n)
    train = np.zeros(n, dtype=bool)
    val = np.zeros(n, dtype=bool)
    test = np.zeros(n, dtype=bool)
    train[order[:n_tr]] = True
    val[order[n_tr : n_tr + n_va]] = True
    test[order[n_tr + n_va :]] = True
    return train, val, test


# ---------------------------------------------------------------------------
# Node-level datasets (Table 7 analogues; sizes scaled for 1-core budget)
# ---------------------------------------------------------------------------

NODE_SPECS = {
    # name:        (N,     F,    C,  m, label_frac, assort)
    "synth-cora": (2708, 1433, 7, 2, 0.0517, 0.85),
    "synth-citeseer": (3327, 1200, 6, 2, 0.0361, 0.85),
    # PubMed 19717 → 6000 nodes, label rate kept at 0.30%: the Local-Gradient
    # motivation (≈18 labeled nodes) survives the rescale.
    "synth-pubmed": (6000, 500, 3, 3, 0.0030, 0.80),
    # ogbn-arxiv 169343 → 12000 nodes, 53.7% labeled as in Table 5.
    "synth-arxiv": (12000, 128, 23, 4, 0.5370, 0.70),
}


def make_node_dataset(name: str, seed: int = 0) -> NodeDataset:
    n, f, c, m, label_frac, assort = NODE_SPECS[name]
    rng = np.random.default_rng(seed * 9176 + _stable_hash(name))
    labels = rng.integers(0, c, size=n).astype(np.int32)
    src, dst = _preferential_attachment(rng, n, m, labels, assort)
    indptr, indices = _edges_to_csr(n, src, dst)
    binary = name in ("synth-cora", "synth-citeseer")
    if binary:
        feats = _bow_features(rng, labels, f, c, active=20)
    else:
        # dense tf-idf-like features: class centroid + noise
        centroids = rng.normal(0.0, 1.0, size=(c, f)).astype(np.float32)
        feats = centroids[labels] + rng.normal(0.0, 0.8, size=(n, f)).astype(
            np.float32
        )
    train, val, test = _splits(rng, n, label_frac)
    return NodeDataset(
        name=name,
        indptr=indptr,
        indices=indices,
        features=feats.astype(np.float32),
        labels=labels,
        train_mask=train,
        val_mask=val,
        test_mask=test,
        num_classes=c,
        binary_features=binary,
    )


# ---------------------------------------------------------------------------
# Graph-level datasets
# ---------------------------------------------------------------------------

GRAPH_SPECS = {
    # name:             (G,   avg_n, F,  C)   C=0 ⇒ regression
    "synth-reddit-b": (600, 200, 8, 2),
    "synth-mnist": (1500, 71, 3, 10),
    "synth-cifar10": (1200, 117, 5, 10),
    "synth-zinc": (1500, 23, 28, 0),
}


def _degree_bucket_features(indptr: np.ndarray, f: int) -> np.ndarray:
    """REDDIT-BINARY has no node features; standard practice (and DQ) uses
    degree encodings.  One-hot of ⌊log2(1+deg)⌋ capped at f-1."""
    deg = np.diff(indptr)
    bucket = np.minimum(np.floor(np.log2(1.0 + deg)).astype(np.int64), f - 1)
    feats = np.zeros((deg.shape[0], f), dtype=np.float32)
    feats[np.arange(deg.shape[0]), bucket] = 1.0
    return feats


def _make_reddit_graph(rng: np.random.Generator, label: int, avg_n: int, f: int):
    """Q/A threads (label 0): few high-degree hubs answering many leaves.
    Discussion threads (label 1): deeper chains, flatter degree profile."""
    n = int(rng.integers(avg_n // 2, avg_n * 2))
    if label == 0:
        hubs = max(2, n // 40)
        src = rng.integers(0, hubs, size=n - hubs)
        dst = np.arange(hubs, n)
        extra = rng.integers(0, n, size=n // 4)
        extra_d = rng.integers(0, hubs, size=n // 4)
        s = np.concatenate([src, extra])
        d = np.concatenate([dst, extra_d])
    else:
        # chain with random back-edges (reply chains)
        s = np.arange(1, n)
        d = np.maximum(s - 1 - rng.integers(0, 4, size=n - 1), 0)
        extra = rng.integers(0, n, size=n // 6)
        extra_d = rng.integers(0, n, size=n // 6)
        s = np.concatenate([s, extra])
        d = np.concatenate([d, extra_d])
    keep = s != d
    s, d = s[keep], d[keep]
    indptr, indices = _edges_to_csr(n, np.concatenate([s, d]), np.concatenate([d, s]))
    return SmallGraph(indptr, indices, _degree_bucket_features(indptr, f))


def _make_superpixel_graph(
    rng: np.random.Generator, label: int, avg_n: int, f: int, c: int
):
    """Superpixel analogue: nodes at random 2D positions, 4-NN edges,
    intensity = class-specific mixture of 2D gaussian blobs + noise."""
    n = int(rng.integers(int(avg_n * 0.8), int(avg_n * 1.2)))
    pos = rng.random((n, 2)).astype(np.float32)
    # class pattern: ``label`` seeds blob centres deterministically
    prng = np.random.default_rng(label * 7919 + 13)
    centers = prng.random((3, 2)).astype(np.float32)
    weights = prng.uniform(0.5, 1.5, size=3).astype(np.float32)
    d2 = ((pos[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
    intensity = (weights[None, :] * np.exp(-d2 / 0.02)).sum(-1)
    intensity += rng.normal(0, 0.08, size=n)
    # k-NN edges (k=4) on positions
    dist = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(dist, np.inf)
    knn = np.argsort(dist, axis=1)[:, :4]
    src = np.repeat(np.arange(n), 4)
    dst = knn.reshape(-1)
    indptr, indices = _edges_to_csr(
        n, np.concatenate([src, dst]), np.concatenate([dst, src])
    )
    extra = np.zeros((n, max(0, f - 3)), dtype=np.float32)
    feats = np.concatenate(
        [intensity[:, None].astype(np.float32), pos, extra], axis=1
    )[:, :f]
    return SmallGraph(indptr, indices, feats)


def _make_molecule_graph(rng: np.random.Generator, f: int):
    """ZINC analogue: a random tree plus ring closures; one-hot atom types.
    Regression target = planted 'penalized-logP-like' functional of motif
    counts (ring atoms, leaves, heteroatoms), plus small noise."""
    n = int(rng.integers(12, 38))
    parent = np.array([rng.integers(0, max(i, 1)) for i in range(1, n)])
    src = np.arange(1, n)
    dst = parent
    n_rings = rng.integers(0, 3)
    ring_atoms = set()
    for _ in range(n_rings):
        a, b = rng.integers(0, n, size=2)
        if a != b:
            src = np.append(src, a)
            dst = np.append(dst, b)
            ring_atoms.update((int(a), int(b)))
    indptr, indices = _edges_to_csr(
        n, np.concatenate([src, dst]), np.concatenate([dst, src])
    )
    atom_type = rng.choice(f, size=n, p=_atom_probs(f))
    feats = np.zeros((n, f), dtype=np.float32)
    feats[np.arange(n), atom_type] = 1.0
    deg = np.diff(indptr)
    hetero = (atom_type >= 4).sum()
    target = (
        0.15 * len(ring_atoms)
        - 0.10 * (deg == 1).sum()
        + 0.05 * hetero
        - 0.02 * n
        + rng.normal(0, 0.05)
    )
    return SmallGraph(indptr, indices, feats), np.float32(target)


def _atom_probs(f: int) -> np.ndarray:
    p = np.ones(f)
    p[:4] = f  # carbon-like types dominate
    return p / p.sum()


def make_graph_dataset(name: str, seed: int = 0) -> GraphDataset:
    g, avg_n, f, c = GRAPH_SPECS[name]
    rng = np.random.default_rng(seed * 7919 + _stable_hash(name))
    graphs: list[SmallGraph] = []
    targets = []
    for i in range(g):
        if name == "synth-reddit-b":
            label = i % 2
            graphs.append(_make_reddit_graph(rng, label, avg_n, f))
            targets.append(label)
        elif name in ("synth-mnist", "synth-cifar10"):
            label = i % c
            graphs.append(_make_superpixel_graph(rng, label, avg_n, f, c))
            targets.append(label)
        else:  # synth-zinc
            graph, y = _make_molecule_graph(rng, f)
            graphs.append(graph)
            targets.append(y)
    tgt = (
        np.asarray(targets, dtype=np.float32)
        if c == 0
        else np.asarray(targets, dtype=np.int64)
    )
    return GraphDataset(name, graphs, f, c, tgt)


# ---------------------------------------------------------------------------
# Binary serialisation (shared with rust/src/graph/io.rs)
# ---------------------------------------------------------------------------


def save_node_dataset(ds: NodeDataset, path: str) -> None:
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<II", VERSION, 0))
        fh.write(
            struct.pack(
                "<IIII", ds.num_nodes, ds.num_features, ds.num_classes, ds.num_edges
            )
        )
        fh.write(ds.indptr.astype("<u4").tobytes())
        fh.write(ds.indices.astype("<u4").tobytes())
        fh.write(ds.features.astype("<f4").tobytes())
        fh.write(ds.labels.astype("<i4").tobytes())
        for mask in (ds.train_mask, ds.val_mask, ds.test_mask):
            fh.write(mask.astype(np.uint8).tobytes())


def save_graph_dataset(ds: GraphDataset, path: str) -> None:
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<II", VERSION, 1))
        fh.write(struct.pack("<III", ds.num_graphs, ds.num_features, ds.num_classes))
        for g, y in zip(ds.graphs, ds.targets):
            fh.write(struct.pack("<II", g.num_nodes, int(g.indices.shape[0])))
            fh.write(g.indptr.astype("<u4").tobytes())
            fh.write(g.indices.astype("<u4").tobytes())
            fh.write(g.features.astype("<f4").tobytes())
            if ds.num_classes == 0:
                fh.write(struct.pack("<f", float(y)))
            else:
                fh.write(struct.pack("<i", int(y)))


def build_all(out_dir: str, seed: int = 0, names=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for name in names or list(NODE_SPECS) + list(GRAPH_SPECS):
        path = os.path.join(out_dir, f"{name}.bin")
        if os.path.exists(path):
            continue
        if name in NODE_SPECS:
            save_node_dataset(make_node_dataset(name, seed), path)
        else:
            save_graph_dataset(make_graph_dataset(name, seed), path)
        print(f"wrote {path}")


if __name__ == "__main__":
    import sys

    build_all(sys.argv[1] if len(sys.argv) > 1 else "../artifacts/data")
