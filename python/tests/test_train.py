"""End-to-end training smoke tests (tiny budgets) + AOT export round-trip."""

import json
import os

import numpy as np
import pytest

import compile.train as T
from compile import datasets as D
from compile.train import TrainConfig


@pytest.fixture(autouse=True)
def _tmp_results(tmp_path, monkeypatch):
    monkeypatch.setenv("A2Q_RESULTS", str(tmp_path))
    yield


def _node_cfg(**kw):
    base = dict(dataset="synth-cora", arch="gcn", method="a2q", epochs=8,
                hidden=8, penalty_warmup=2)
    base.update(kw)
    return TrainConfig(**base)


class TestNodeTraining:
    def test_loss_decreases_and_above_chance(self):
        blob, _ = T.train_node(_node_cfg(epochs=25), use_cache=False)[:2]
        hist = blob["history"]
        assert hist[-1][1] < hist[0][1]  # train loss decreased
        assert blob["accuracy"] > 1.0 / 7 + 0.1  # well above chance

    def test_local_gradient_learns_steps_for_unlabeled_nodes(self):
        """§3.2: steps of nodes with zero task gradient must still move."""
        cfg = _node_cfg(epochs=5)
        T.train_node(cfg, use_cache=False)
        tree, mcfg, qcfg, ds = T.rebuild_tree(cfg)
        s = np.asarray(tree["qp"]["feat"][1]["s"])
        # practically all nodes moved away from the N(0.01, 0.01) init
        moved = np.abs(s - 0.01) > 1e-4
        assert moved.mean() > 0.9

    def test_fp32_has_no_qparams(self):
        cfg = _node_cfg(method="fp32", epochs=4)
        blob, _ = T.train_node(cfg, use_cache=False)[:2]
        assert blob["avg_bits"] == 32.0
        assert blob["bits_hist"] == []

    def test_grad_zero_fraction_probe(self):
        blob, _ = T.train_node(_node_cfg(epochs=4), use_cache=False)[:2]
        assert 0.0 <= blob["grad_zero_frac"] <= 1.0

    def test_cache_roundtrip(self):
        cfg = _node_cfg(epochs=4)
        blob1, p1 = T.train_node(cfg, use_cache=False)[:2]
        blob2, p2 = T.train_node(cfg, use_cache=True)[:2]
        assert p1 == p2
        assert blob1["accuracy"] == blob2["accuracy"]

    def test_ablation_flags(self):
        cfg = _node_cfg(epochs=4, learn_bits=False)
        blob, _ = T.train_node(cfg, use_cache=False)[:2]
        # bits must stay at the 4-bit init
        assert blob["avg_bits"] == pytest.approx(4.0)

    def test_dq_baseline_runs(self):
        blob, _ = T.train_node(_node_cfg(method="dq", epochs=4), use_cache=False)[:2]
        assert blob["avg_bits"] == 4.0

    def test_manual_bits_assignment(self):
        cfg = _node_cfg(method="manual", epochs=4, manual_avg_bits=3.0)
        blob, _ = T.train_node(cfg, use_cache=False)[:2]
        assert blob["avg_bits"] == pytest.approx(3.0, abs=0.3)


class TestGraphTraining:
    def test_zinc_regression_improves(self):
        cfg = TrainConfig(dataset="synth-zinc", arch="gcn", method="a2q",
                          epochs=6, hidden=16, layers=2, batch_graphs=16,
                          penalty_warmup=2, lam=0.5, target_avg_bits=3.5)
        blob, _ = T.train_graph(cfg, use_cache=False)[:2]
        assert blob["metric_name"] == "mae"
        hist = blob["history"]
        assert hist[-1][1] < hist[0][1]

    def test_nns_groups_saved(self):
        cfg = TrainConfig(dataset="synth-zinc", arch="gin", method="a2q",
                          epochs=3, hidden=16, layers=2, batch_graphs=16,
                          nns_m=64)
        T.train_graph(cfg, use_cache=False)
        tree, mcfg, qcfg, _ = T.rebuild_tree(cfg)
        assert tree["qp"]["feat"][0]["s"].shape == (64,)
        assert qcfg.nns


class TestExport:
    def test_export_writes_complete_artifact(self, tmp_path):
        from compile.aot import export_variant

        cfg = _node_cfg(epochs=3)
        man_path = export_variant(cfg, str(tmp_path / "models"))
        with open(man_path) as fh:
            man = json.load(fh)
        d = tmp_path / "models"
        assert (d / man["hlo"]).exists()
        assert (d / man["weights_bin"]).exists()
        assert man["expected_head"]
        assert man["num_nodes"] == 2708
        # weights file length matches the tensor table
        total = sum(int(np.prod(t["shape"]) or 1) for t in man["tensors"])
        assert os.path.getsize(d / man["weights_bin"]) == 4 * total

    def test_hlo_text_parses_back(self, tmp_path):
        """The emitted HLO text must be loadable (the rust runtime contract)."""
        from compile.aot import export_variant
        from jax._src.lib import xla_client as xc

        cfg = _node_cfg(epochs=3)
        man_path = export_variant(cfg, str(tmp_path / "models"))
        with open(man_path) as fh:
            man = json.load(fh)
        text = (tmp_path / "models" / man["hlo"]).read_text()
        assert "ENTRY" in text and "parameter(0)" in text
