"""Dataset-generator tests: the synthetic analogues must reproduce the graph
properties A²Q's mechanism depends on (DESIGN.md §3 substitution table)."""

import os
import struct

import numpy as np
import pytest

from compile import datasets as D


@pytest.fixture(scope="module")
def cora():
    return D.make_node_dataset("synth-cora", seed=0)


class TestNodeDatasets:
    def test_spec_counts(self, cora):
        assert cora.num_nodes == 2708
        assert cora.num_features == 1433
        assert cora.num_classes == 7

    def test_label_rate_matches_table5(self, cora):
        rate = cora.train_mask.mean()
        assert rate == pytest.approx(0.0517, abs=0.005)

    def test_pubmed_tiny_label_rate(self):
        ds = D.make_node_dataset("synth-pubmed", seed=0)
        assert ds.train_mask.sum() <= 25  # ~0.30% of 6000

    def test_masks_disjoint(self, cora):
        overlap = (
            cora.train_mask.astype(int)
            + cora.val_mask.astype(int)
            + cora.test_mask.astype(int)
        )
        assert overlap.max() == 1

    def test_power_law_degree_distribution(self, cora):
        """Most nodes low-degree, a heavy tail of hubs (Fig. 8)."""
        deg = cora.in_degrees()
        assert np.median(deg) <= 6
        assert deg.max() >= 20 * np.median(deg)
        frac_low = (deg <= 2 * np.median(deg)).mean()
        assert frac_low > 0.6

    def test_aggregation_magnitude_correlates_with_degree(self, cora):
        """Fig. 1: mean |sum-aggregated feature| grows with in-degree."""
        deg = cora.in_degrees()
        x = cora.features
        src, dst = cora.edge_list()
        agg = np.zeros_like(x)
        np.add.at(agg, dst, x[src])
        mag = np.abs(agg).mean(axis=1)
        lo = mag[deg <= np.percentile(deg, 30)].mean()
        hi = mag[deg >= np.percentile(deg, 90)].mean()
        assert hi > 2.0 * lo

    def test_binary_features_are_01(self, cora):
        vals = np.unique(cora.features)
        assert set(vals.tolist()) <= {0.0, 1.0}

    def test_csr_valid(self, cora):
        assert cora.indptr[0] == 0
        assert cora.indptr[-1] == cora.indices.shape[0]
        assert (np.diff(cora.indptr.astype(np.int64)) >= 0).all()
        assert cora.indices.max() < cora.num_nodes

    def test_deterministic(self):
        a = D.make_node_dataset("synth-citeseer", seed=0)
        b = D.make_node_dataset("synth-citeseer", seed=0)
        np.testing.assert_array_equal(a.indices, b.indices)
        np.testing.assert_array_equal(a.features, b.features)


class TestGraphDatasets:
    def test_variable_node_counts(self):
        ds = D.make_graph_dataset("synth-zinc", seed=0)
        sizes = {g.num_nodes for g in ds.graphs}
        assert len(sizes) > 5  # NNS motivation: sizes vary

    def test_reddit_classes_differ_in_hub_structure(self):
        ds = D.make_graph_dataset("synth-reddit-b", seed=0)
        max_deg_frac = []
        for g, y in zip(ds.graphs[:60], ds.targets[:60]):
            deg = g.in_degrees()
            max_deg_frac.append((y, deg.max() / max(deg.mean(), 1)))
        qa = np.mean([v for y, v in max_deg_frac if y == 0])
        disc = np.mean([v for y, v in max_deg_frac if y == 1])
        assert qa > disc  # Q/A threads are hubbier

    def test_zinc_regression_targets(self):
        ds = D.make_graph_dataset("synth-zinc", seed=0)
        assert ds.num_classes == 0
        assert ds.targets.dtype == np.float32
        assert np.std(ds.targets) > 0.05

    def test_superpixel_features_have_position_channels(self):
        ds = D.make_graph_dataset("synth-mnist", seed=0)
        g = ds.graphs[0]
        assert g.features.shape[1] == 3
        # channels 1-2 are positions in [0,1]
        assert g.features[:, 1:].min() >= 0.0
        assert g.features[:, 1:].max() <= 1.0


class TestSerialisation:
    def test_node_roundtrip_header(self, tmp_path, cora):
        path = os.path.join(tmp_path, "c.bin")
        D.save_node_dataset(cora, path)
        with open(path, "rb") as fh:
            assert fh.read(4) == b"A2QD"
            ver, kind = struct.unpack("<II", fh.read(8))
            assert (ver, kind) == (D.VERSION, 0)
            n, f, c, nnz = struct.unpack("<IIII", fh.read(16))
            assert (n, f, c, nnz) == (
                cora.num_nodes, cora.num_features, cora.num_classes, cora.num_edges,
            )

    def test_node_file_size_exact(self, tmp_path, cora):
        path = os.path.join(tmp_path, "c.bin")
        D.save_node_dataset(cora, path)
        n, f = cora.num_nodes, cora.num_features
        nnz = cora.num_edges
        want = 4 + 8 + 16 + 4 * (n + 1) + 4 * nnz + 4 * n * f + 4 * n + 3 * n
        assert os.path.getsize(path) == want

    def test_graph_file_roundtrip_counts(self, tmp_path):
        ds = D.make_graph_dataset("synth-zinc", seed=0)
        path = os.path.join(tmp_path, "z.bin")
        D.save_graph_dataset(ds, path)
        with open(path, "rb") as fh:
            assert fh.read(4) == b"A2QD"
            _, kind = struct.unpack("<II", fh.read(8))
            assert kind == 1
            g, f, c = struct.unpack("<III", fh.read(12))
            assert (g, f, c) == (ds.num_graphs, ds.num_features, 0)
