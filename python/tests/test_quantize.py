"""L2 quantizer semantics: Eq. 10 gradients, Local Gradient, NNS, penalties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as Q
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def _case(seed, n=8, f=16):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    s = jnp.asarray(rng.uniform(0.02, 0.2, n).astype(np.float32))
    b = jnp.asarray(rng.uniform(2.0, 7.0, n).astype(np.float32))
    return x, s, b


class TestForwardSemantics:
    @given(st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_forward_matches_ref(self, seed):
        x, s, b = _case(seed)
        got = Q.a2q_quantize(x, s, b, True, "global")
        want = ref.quantize_ref(x, s, b, signed=True)
        np.testing.assert_allclose(got, want, atol=1e-6)

    @given(st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_codes_within_levels(self, seed):
        """|x̄| ≤ 2^{b-1} − 1 — the fixed-point representability invariant."""
        x, s, b = _case(seed)
        xq = np.asarray(Q.a2q_quantize(x, s, b, True, "global"))
        codes = np.abs(xq / np.maximum(np.asarray(s)[:, None], 1e-9))
        levels = 2 ** (np.round(np.asarray(b)) - 1) - 1
        assert (codes <= levels[:, None] + 1e-4).all()

    @given(st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_inrange_error_below_half_step(self, seed):
        x, s, b = _case(seed)
        xq = np.asarray(Q.a2q_quantize(x, s, b, True, "global"))
        s_col = np.asarray(s)[:, None]
        levels = (2 ** (np.round(np.asarray(b)) - 1) - 1)[:, None]
        in_range = np.abs(np.asarray(x)) < s_col * levels
        err = np.abs(xq - np.asarray(x))
        assert (err[in_range] <= s_col.repeat(x.shape[1], 1)[in_range] / 2 + 1e-6).all()


class TestGradients:
    def test_ste_passes_inrange_blocks_clipped(self):
        x = jnp.asarray([[0.05, 10.0]])
        s = jnp.asarray([0.1])
        b = jnp.asarray([4.0])
        g = jax.grad(lambda xx: jnp.sum(Q.a2q_quantize(xx, s, b, True, "global")))(x)
        assert g[0, 0] == 1.0  # in range
        assert g[0, 1] == 0.0  # clipped

    def test_step_gradient_eq10_inrange(self):
        """In-range: dxq/ds = (xq - x)/s (Eq. 10 upper row)."""
        x = jnp.asarray([[0.234]])
        s = jnp.asarray([0.1])
        b = jnp.asarray([6.0])
        gs = jax.grad(
            lambda ss: jnp.sum(Q.a2q_quantize(x, ss, b, True, "global"))
        )(s)
        xq = float(Q.a2q_quantize(x, s, b, True, "global")[0, 0])
        assert gs[0] == pytest.approx((xq - 0.234) / 0.1, rel=1e-5)

    def test_step_gradient_eq10_clipped(self):
        x = jnp.asarray([[99.0]])
        s = jnp.asarray([0.1])
        b = jnp.asarray([4.0])
        gs = jax.grad(
            lambda ss: jnp.sum(Q.a2q_quantize(x, ss, b, True, "global"))
        )(s)
        assert gs[0] == pytest.approx(2**3 - 1)  # sign(x)·(2^{b-1}−1)

    def test_bits_gradient_zero_inrange_nonzero_clipped(self):
        x = jnp.asarray([[0.05, 99.0]])
        s = jnp.asarray([0.1])
        b = jnp.asarray([4.0])
        gb = jax.grad(
            lambda bb: jnp.sum(Q.a2q_quantize(x, s, bb, True, "global"))
        )(b)
        # only the clipped element contributes: 2^{b-1}·ln2·s
        assert gb[0] == pytest.approx(2**3 * np.log(2) * 0.1, rel=1e-5)

    def test_local_gradient_nonzero_when_task_grad_zero(self):
        """§3.2: with a zero upstream cotangent, global grads vanish but
        Local Gradient still trains (s, b)."""
        x, s, b = _case(3)

        def loss_global(ss):
            xq = Q.a2q_quantize(x, ss, b, True, "global")
            return jnp.sum(xq * 0.0)  # zero task gradient

        def loss_local(ss):
            xq = Q.a2q_quantize(x, ss, b, True, "local")
            return jnp.sum(xq * 0.0)

        g_global = jax.grad(loss_global)(s)
        g_local = jax.grad(loss_local)(s)
        assert float(jnp.abs(g_global).max()) == 0.0
        assert float(jnp.abs(g_local).max()) > 0.0

    def test_local_gradient_matches_eq7(self):
        """Eq. 7: dE/ds = (1/d) Σ sign(xq−x)·dxq/ds."""
        x, s, b = _case(11, n=4, f=8)

        def loss(ss):
            return jnp.sum(Q.a2q_quantize(x, ss, b, True, "local"))

        g = jax.grad(loss)(s)
        xq = np.asarray(Q.a2q_quantize(x, s, b, True, "global"))
        xn, sn, bn = np.asarray(x), np.asarray(s), np.asarray(b)
        lv = 2 ** (np.round(bn) - 1) - 1
        in_range = np.abs(xn) < sn[:, None] * lv[:, None]
        dxq_ds = np.where(
            in_range, (xq - xn) / sn[:, None], np.sign(xn) * lv[:, None]
        )
        want = (np.sign(xq - xn) / x.shape[1] * dxq_ds).sum(-1)
        np.testing.assert_allclose(np.asarray(g), want, rtol=1e-4, atol=1e-6)


class TestNnsTraining:
    def test_index_matches_ref_and_grads_scatter(self):
        x, _, _ = _case(5, n=32, f=8)
        sg = jnp.asarray(np.linspace(0.01, 0.5, 16).astype(np.float32))
        bg = jnp.full((16,), 4.0)
        (xq, idx) = Q.nns_quantize_train(x, sg, bg)
        want_idx, _, _ = ref.nns_select_ref(x, sg, bg)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(want_idx))
        # gradient w.r.t. group steps only lands on used groups
        g = jax.grad(lambda ss: jnp.sum(Q.nns_quantize_train(x, ss, bg)[0] ** 2))(sg)
        used = set(np.asarray(idx).tolist())
        for j in range(16):
            if j not in used:
                assert float(g[j]) == 0.0


class TestMemoryPenalty:
    def test_zero_at_target(self):
        bits = [jnp.full((100,), 4.0)]
        target = 100 * 16 * 4 / 8192
        assert float(Q.memory_penalty(bits, [16], target)) == pytest.approx(0.0)

    def test_gradient_sign_pulls_toward_target(self):
        bits = [jnp.full((100,), 6.0)]
        target = 100 * 16 * 2 / 8192  # want 2 bits
        g = jax.grad(lambda b: Q.memory_penalty([b], [16], target))(bits[0])
        assert (np.asarray(g) > 0).all()  # positive grad → bits decrease

    def test_average_bits_weighted_by_dim(self):
        bits = [jnp.full((10,), 2.0), jnp.full((10,), 6.0)]
        avg = float(Q.average_bits(bits, [1, 3]))
        assert avg == pytest.approx((2 * 1 + 6 * 3) / 4)

    def test_compression_ratio(self):
        assert Q.compression_ratio(1.7) == pytest.approx(32 / 1.7)


class TestBaselines:
    def test_dq_protection_bypasses_quant(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)).astype(np.float32))
        s = jnp.asarray(0.05)
        prot = jnp.asarray([1.0, 0.0, 1.0, 0.0])
        out = np.asarray(Q.dq_quantize(x, s, prot))
        np.testing.assert_allclose(out[0], np.asarray(x)[0])  # protected
        assert not np.allclose(out[1], np.asarray(x)[1])  # quantized

    def test_binary_is_sign_times_rowmean(self):
        x = jnp.asarray([[1.0, -2.0, 3.0]])
        out = np.asarray(Q.binary_quantize(x))
        np.testing.assert_allclose(np.abs(out), 2.0 * np.ones((1, 3)))
        np.testing.assert_allclose(np.sign(out), [[1, -1, 1]])

    def test_manual_bits_match_budget(self):
        deg = np.arange(100)
        bits = np.asarray(Q.manual_bits_by_degree(deg, 2.2))
        assert bits.mean() == pytest.approx(2.2, abs=0.02)
        # high-degree nodes get the high bitwidth
        assert bits[np.argsort(-deg)[:10]].mean() >= bits.mean()

    def test_lsq_forward(self):
        x = jnp.asarray([[0.123, -0.04]])
        out = np.asarray(Q.lsq_quantize(x, jnp.asarray(0.05), 4.0, True))
        np.testing.assert_allclose(out, [[0.1, -0.05]], atol=1e-6)
