"""L2 model tests: shapes, quantization plumbing, batching, aggregation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets as D
from compile import models as M
from compile import quantize as Q


def tiny_node_ds():
    return D.make_node_dataset("synth-cora", seed=0)


def _mini_graph():
    """4-node path graph 0-1-2-3 (undirected)."""
    src = np.asarray([0, 1, 1, 2, 2, 3])
    dst = np.asarray([1, 0, 2, 1, 3, 2])
    indptr, indices = D._edges_to_csr(4, src, dst)
    return indptr, indices


@pytest.fixture(scope="module")
def edges4():
    indptr, indices = _mini_graph()
    return M.build_edges(indptr, indices)


class TestEdges:
    def test_gcn_norm_includes_self_loops(self, edges4):
        # 4 nodes path: 6 directed edges + 4 self loops
        assert edges4.src.shape[0] == 10
        # degree-normalised weights are symmetric positive
        assert float(jnp.min(edges4.gcn_w)) > 0.0

    def test_self_loops_excluded_from_gin_sum(self, edges4):
        assert float(jnp.sum(edges4.sum_w)) == 6.0

    def test_aggregate_path_graph(self, edges4):
        x = jnp.asarray([[1.0], [2.0], [3.0], [4.0]])
        out = np.asarray(M.aggregate(x, edges4, edges4.sum_w))
        np.testing.assert_allclose(out[:, 0], [2.0, 4.0, 6.0, 3.0])


def _forward_shapes(arch, method, readout="none"):
    indptr, indices = _mini_graph()
    edges = M.build_edges(indptr, indices)
    cfg = M.ModelConfig(
        arch=arch, in_dim=6, hidden=8, out_dim=3, layers=2,
        heads=2, dropout=0.0, readout=readout,
    )
    qcfg = M.QuantConfig(method=method, nns=readout != "none" and method != "fp32")
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    qp = M.init_qparams(rng, cfg, qcfg, 4 if readout == "none" else 16)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 6)).astype(np.float32))
    if readout != "none":
        edges = M.EdgeData(
            src=edges.src, dst=edges.dst, gcn_w=edges.gcn_w, sum_w=edges.sum_w,
            num_nodes=4, node2graph=jnp.zeros(4, jnp.int32), num_graphs=1,
            node_mask=jnp.ones(4),
        )
    out, _ = M.forward(
        params, qp, x, edges, cfg, qcfg, prot_mask=jnp.zeros(4)
    )
    return out


class TestForwardShapes:
    @pytest.mark.parametrize("arch", ["gcn", "gin", "gat"])
    @pytest.mark.parametrize("method", ["fp32", "a2q", "dq", "binary"])
    def test_node_level_output_shape(self, arch, method):
        out = _forward_shapes(arch, method)
        assert out.shape == (4, 3)
        assert bool(jnp.all(jnp.isfinite(out)))

    @pytest.mark.parametrize("arch", ["gcn", "gin"])
    def test_graph_level_readout_shape(self, arch):
        out = _forward_shapes(arch, "a2q", readout="mean")
        assert out.shape == (1, 3)

    def test_quantization_changes_output(self):
        a = _forward_shapes("gcn", "fp32")
        b = _forward_shapes("gcn", "a2q")
        assert not np.allclose(np.asarray(a), np.asarray(b))

    def test_pallas_impl_matches_jnp_impl(self):
        """The exported (pallas) forward must agree with the training
        (custom-vjp) forward — same Eq. 1 semantics."""
        indptr, indices = _mini_graph()
        edges = M.build_edges(indptr, indices)
        cfg = M.ModelConfig(arch="gcn", in_dim=6, hidden=8, out_dim=3,
                            layers=2, dropout=0.0)
        qcfg = M.QuantConfig(method="a2q")
        rng = jax.random.PRNGKey(1)
        params = M.init_params(rng, cfg)
        qp = M.init_qparams(rng, cfg, qcfg, 4)
        x = jnp.asarray(
            np.random.default_rng(1).normal(size=(4, 6)).astype(np.float32)
        )
        zero = jnp.zeros(4)
        out_jnp, _ = M.forward(params, qp, x, edges, cfg, qcfg, prot_mask=zero)
        out_pl, _ = M.forward(
            params, qp, x, edges, cfg, qcfg, prot_mask=zero, impl="pallas"
        )
        np.testing.assert_allclose(
            np.asarray(out_jnp), np.asarray(out_pl), rtol=1e-5, atol=1e-5
        )


class TestGraphBatching:
    def test_pad_batch_conserves_graphs(self):
        ds = D.make_graph_dataset("synth-zinc", seed=0)
        graphs = ds.graphs[:4]
        total_n = sum(g.num_nodes for g in graphs)
        feats, edges = M.pad_graph_batch(graphs, total_n + 10, 4096, ds.num_features)
        n2g = np.asarray(edges.node2graph)
        for gi, g in enumerate(graphs):
            assert (n2g == gi).sum() == g.num_nodes
        # padding nodes route to the dummy segment
        assert (n2g == len(graphs)).sum() == 10
        assert float(jnp.sum(edges.node_mask)) == total_n

    def test_padding_edges_have_zero_weight(self):
        ds = D.make_graph_dataset("synth-zinc", seed=0)
        feats, edges = M.pad_graph_batch(ds.graphs[:2], 200, 2048, ds.num_features)
        w = np.asarray(edges.gcn_w)
        nz = int((w > 0).sum())
        real_e = sum(
            g.indices.shape[0] + g.num_nodes for g in ds.graphs[:2]
        )
        assert nz == real_e

    def test_block_diagonal_no_cross_graph_messages(self):
        ds = D.make_graph_dataset("synth-zinc", seed=0)
        feats, edges = M.pad_graph_batch(ds.graphs[:3], 150, 1024, ds.num_features)
        src = np.asarray(edges.src)
        dst = np.asarray(edges.dst)
        n2g = np.asarray(edges.node2graph)
        w = np.asarray(edges.gcn_w)
        real = w > 0
        assert (n2g[src[real]] == n2g[dst[real]]).all()


class TestBitsAccounting:
    def test_feature_bits_and_dims_cover_all_maps(self):
        cfg = M.ModelConfig(arch="gin", in_dim=6, hidden=8, out_dim=3, layers=2)
        qcfg = M.QuantConfig(method="a2q")
        qp = M.init_qparams(jax.random.PRNGKey(0), cfg, qcfg, 10)
        bits, dims = M.feature_bits_and_dims(qp, cfg)
        # 2 layer inputs + 2 GIN hidden maps
        assert len(bits) == 4
        assert dims[0] == 6 and dims[1] == 8

    def test_avg_bits_at_init_is_init_bits(self):
        cfg = M.ModelConfig(arch="gcn", in_dim=6, hidden=8, out_dim=3, layers=2)
        qcfg = M.QuantConfig(method="a2q", init_bits=4.0)
        qp = M.init_qparams(jax.random.PRNGKey(0), cfg, qcfg, 10)
        bits, dims = M.feature_bits_and_dims(qp, cfg)
        assert float(Q.average_bits(bits, dims)) == pytest.approx(4.0)
