"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aaq, nns, qmatmul, ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@st.composite
def quant_case(draw):
    n = draw(st.integers(1, 300))
    f = draw(st.integers(1, 96))
    seed = draw(st.integers(0, 2**31 - 1))
    signed = draw(st.booleans())
    return n, f, seed, signed


class TestAaqKernel:
    @given(quant_case())
    @settings(**SETTINGS)
    def test_matches_ref(self, case):
        n, f, seed, signed = case
        rng = np.random.default_rng(seed)
        x = rand(rng, n, f)
        if not signed:
            x = jnp.abs(x)
        s = jnp.asarray(rng.uniform(0.005, 0.3, n).astype(np.float32))
        b = jnp.asarray(rng.uniform(1.0, 8.0, n).astype(np.float32))
        got = aaq.aaq_quantize(x, s, b, signed=signed)
        want = ref.quantize_ref(x, s, b, signed=signed)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_block_boundary_shapes(self):
        """Rows exactly at / around the 128-row block boundary."""
        rng = np.random.default_rng(0)
        for n in (127, 128, 129, 256, 257):
            x = rand(rng, n, 17)
            s = jnp.full((n,), 0.05)
            b = jnp.full((n,), 4.0)
            np.testing.assert_allclose(
                aaq.aaq_quantize(x, s, b), ref.quantize_ref(x, s, b), atol=1e-6
            )

    def test_clipping_saturates_at_levels(self):
        x = jnp.asarray([[100.0, -100.0, 0.1]])
        s = jnp.asarray([0.1])
        b = jnp.asarray([4.0])
        out = np.asarray(aaq.aaq_quantize(x, s, b))
        assert out[0, 0] == pytest.approx(0.1 * 7)  # 2^3 - 1 levels
        assert out[0, 1] == pytest.approx(-0.1 * 7)

    def test_unsigned_clamps_negatives_to_zero(self):
        x = jnp.asarray([[-1.0, 0.5]])
        out = np.asarray(
            aaq.aaq_quantize(x, jnp.asarray([0.1]), jnp.asarray([4.0]), signed=False)
        )
        assert out[0, 0] == 0.0

    def test_vmem_estimate_positive(self):
        assert aaq.vmem_bytes(128, 1433) > 0


class TestQmatmulKernel:
    @given(
        st.integers(1, 200), st.integers(1, 150), st.integers(1, 80),
        st.integers(0, 2**31 - 1),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        xb = jnp.round(rand(rng, m, k) * 7)
        wb = jnp.round(rand(rng, k, n) * 7)
        sx = jnp.asarray(rng.uniform(0.01, 0.2, m).astype(np.float32))
        sw = jnp.asarray(rng.uniform(0.01, 0.2, n).astype(np.float32))
        got = qmatmul.qmatmul(xb, wb, sx, sw)
        want = ref.qmatmul_ref(xb, wb, sx, sw)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_tile_boundaries(self):
        rng = np.random.default_rng(1)
        for m, k, n in ((128, 128, 128), (129, 127, 1), (256, 64, 130)):
            xb = jnp.round(rand(rng, m, k) * 3)
            wb = jnp.round(rand(rng, k, n) * 3)
            sx = jnp.full((m,), 0.1)
            sw = jnp.full((n,), 0.1)
            np.testing.assert_allclose(
                qmatmul.qmatmul(xb, wb, sx, sw),
                ref.qmatmul_ref(xb, wb, sx, sw),
                rtol=1e-5,
                atol=1e-5,
            )

    def test_flops_model(self):
        assert qmatmul.flops(2, 3, 4) == 48
        assert qmatmul.vmem_bytes(128, 128, 128) <= 16 * 2**20


class TestNnsKernel:
    @given(
        st.integers(1, 200), st.integers(1, 48), st.integers(2, 64),
        st.integers(0, 2**31 - 1),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, n, f, m, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, n, f)
        sg = jnp.asarray(rng.uniform(0.005, 0.4, m).astype(np.float32))
        bg = jnp.asarray(rng.uniform(1.0, 8.0, m).astype(np.float32))
        xq, idx = nns.nns_quantize(x, sg, bg)
        want_idx, _, _ = ref.nns_select_ref(x, sg, bg)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(want_idx))
        np.testing.assert_allclose(
            xq, ref.nns_quantize_ref(x, sg, bg), rtol=1e-6, atol=1e-6
        )

    def test_selects_nearest_qmax(self):
        # two groups: qmax = 0.7 and 7.0; node max 0.6 must take group 0
        sg = jnp.asarray([0.1, 1.0])
        bg = jnp.asarray([4.0, 4.0])
        x = jnp.asarray([[0.6, 0.1], [6.5, 0.2]])
        _, idx = nns.nns_quantize(x, sg, bg)
        assert idx.tolist() == [0, 1]


class TestCsrAggregateRef:
    def test_simple_sum(self):
        x = jnp.asarray([[1.0], [2.0], [4.0]])
        src = jnp.asarray([0, 1, 2])
        dst = jnp.asarray([1, 2, 0])
        w = jnp.ones(3)
        out = ref.csr_aggregate_ref(x, src, dst, w, 3)
        np.testing.assert_allclose(out[:, 0], [4.0, 1.0, 2.0])
