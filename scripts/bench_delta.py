#!/usr/bin/env python3
"""Diff freshly emitted BENCH_*.json files against committed baselines.

Warn-only CI tooling: prints a GitHub-flavoured-markdown speedup/regression
table (suitable for $GITHUB_STEP_SUMMARY) and ALWAYS exits 0 — bench noise
on shared runners must never gate a merge.  Regressions beyond the warn
threshold are flagged with a warning emoji so they are visible in the job
summary without being load-bearing.

Usage:
    python3 scripts/bench_delta.py [--baselines bench/baselines] \
        [--threshold 0.10] BENCH_*.json

Baseline files are byte-identical copies of a trusted run's BENCH_<name>.json
(the `bench-json` CI artifact), committed under --baselines with the same
file name.  Benchmarks or metrics without a baseline are listed with their
current values only.
"""

import argparse
import json
import os
import sys


def load(path):
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError) as exc:
        print(f"> bench-delta: skipping {path}: {exc}")
        return None


def fmt_ns(ns):
    if ns < 1e3:
        return f"{ns:.0f} ns"
    if ns < 1e6:
        return f"{ns / 1e3:.2f} us"
    if ns < 1e9:
        return f"{ns / 1e6:.2f} ms"
    return f"{ns / 1e9:.3f} s"


def by_name(entries):
    return {e.get("name"): e for e in entries if isinstance(e, dict) and "name" in e}


def diff_file(cur_path, base_dir, threshold):
    cur = load(cur_path)
    if cur is None:
        return
    name = os.path.basename(cur_path)
    base_path = os.path.join(base_dir, name)
    base = load(base_path) if os.path.exists(base_path) else None

    print(f"\n### {name}" + ("" if base else "  (no committed baseline)"))
    print()
    print("| benchmark | baseline | current | speedup |")
    print("| --- | ---: | ---: | ---: |")
    base_benches = by_name(base.get("benchmarks", [])) if base else {}
    for b in cur.get("benchmarks", []):
        bname = b.get("name", "?")
        cur_ns = b.get("median_ns")
        ref = base_benches.get(bname)
        if ref is None or not ref.get("median_ns") or not cur_ns:
            print(f"| `{bname}` | — | {fmt_ns(cur_ns) if cur_ns else '—'} | — |")
            continue
        speedup = ref["median_ns"] / cur_ns
        flag = " ⚠️" if speedup < 1.0 - threshold else ""
        print(
            f"| `{bname}` | {fmt_ns(ref['median_ns'])} | {fmt_ns(cur_ns)} "
            f"| {speedup:.2f}x{flag} |"
        )

    metrics = cur.get("metrics", [])
    if metrics:
        print()
        print("| metric | baseline | current | delta |")
        print("| --- | ---: | ---: | ---: |")
        base_metrics = by_name(base.get("metrics", [])) if base else {}
        for m in metrics:
            mname = m.get("name", "?")
            val = m.get("value")
            unit = m.get("unit", "")
            ref = base_metrics.get(mname)
            if ref is None or ref.get("value") is None or val is None:
                shown = f"{val:.4g} {unit}" if val is not None else "—"
                print(f"| `{mname}` | — | {shown} | — |")
                continue
            delta = val - ref["value"]
            rel = delta / ref["value"] if ref["value"] else float("inf")
            # higher is better for speedup-style metrics; only flag drops
            flag = " ⚠️" if rel < -threshold else ""
            print(
                f"| `{mname}` | {ref['value']:.4g} {unit} | {val:.4g} {unit} "
                f"| {rel:+.1%}{flag} |"
            )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baselines", default="bench/baselines")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative regression beyond which a row is flagged")
    ap.add_argument("files", nargs="*", help="current BENCH_*.json files")
    args = ap.parse_args()

    files = args.files or sorted(
        f for f in os.listdir(".") if f.startswith("BENCH_") and f.endswith(".json")
    )
    print("## Bench delta (warn-only)")
    if not files:
        print("\nno BENCH_*.json files found — nothing to diff")
        return 0
    for path in files:
        diff_file(path, args.baselines, args.threshold)
    print(
        "\n_Baselines live in `bench/baselines/`; refresh by committing a "
        "trusted run's `bench-json` artifact. This step never fails the job._"
    )
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except Exception as exc:  # warn-only by contract
        print(f"> bench-delta: internal error (ignored): {exc}")
        sys.exit(0)
