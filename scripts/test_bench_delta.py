"""Regression tests for scripts/bench_delta.py (the warn-only CI step).

Run via ``python3 -m unittest discover -s scripts`` (the CI "bench-harness
regression tests" step).  Drives the script as a subprocess — the contract
under test is the CLI contract CI relies on: always exit 0, flag
regressions with a warning marker, never flag improvements, and degrade
gracefully when a baseline is missing or malformed.
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_delta.py")


def bench_json(median_by_name, metrics=()):
    return {
        "version": 1,
        "benchmarks": [
            {
                "name": name,
                "median_ns": median,
                "mean_ns": median,
                "std_ns": 0.0,
                "iters_per_sample": 10,
                "samples": 3,
            }
            for name, median in median_by_name.items()
        ],
        "metrics": [
            {"name": name, "value": value, "unit": unit}
            for name, value, unit in metrics
        ],
    }


class BenchDeltaTest(unittest.TestCase):
    def setUp(self):
        self.tmp = tempfile.TemporaryDirectory()
        self.addCleanup(self.tmp.cleanup)
        self.dir = self.tmp.name
        self.baselines = os.path.join(self.dir, "baselines")
        os.mkdir(self.baselines)

    def write(self, relpath, payload):
        path = os.path.join(self.dir, relpath)
        with open(path, "w") as fh:
            if isinstance(payload, str):
                fh.write(payload)
            else:
                json.dump(payload, fh)
        return path

    def run_delta(self, *files):
        proc = subprocess.run(
            [sys.executable, SCRIPT, "--baselines", self.baselines, *files],
            cwd=self.dir,
            capture_output=True,
            text=True,
        )
        return proc

    def test_missing_baseline_lists_current_only_and_exits_zero(self):
        self.write("BENCH_x.json", bench_json({"k/a": 1000.0}))
        proc = self.run_delta("BENCH_x.json")
        self.assertEqual(proc.returncode, 0, proc.stderr)
        self.assertIn("(no committed baseline)", proc.stdout)
        self.assertIn("`k/a`", proc.stdout)
        self.assertNotIn("⚠️", proc.stdout)

    def test_regression_beyond_threshold_is_flagged(self):
        self.write(
            os.path.join("baselines", "BENCH_x.json"),
            bench_json({"k/a": 1000.0}, [("m/speed", 2.0, "x")]),
        )
        # 50% slower benchmark, 50% lower metric: both beyond the 10% default
        self.write("BENCH_x.json", bench_json({"k/a": 1500.0}, [("m/speed", 1.0, "x")]))
        proc = self.run_delta("BENCH_x.json")
        self.assertEqual(proc.returncode, 0, proc.stderr)
        self.assertEqual(proc.stdout.count("⚠️"), 2, proc.stdout)
        self.assertIn("0.67x", proc.stdout)  # 1000/1500 speedup column
        self.assertIn("-50.0%", proc.stdout)  # metric delta column

    def test_improvement_and_within_threshold_not_flagged(self):
        self.write(
            os.path.join("baselines", "BENCH_x.json"),
            bench_json({"k/fast": 1000.0, "k/same": 1000.0}, [("m/speed", 2.0, "x")]),
        )
        # faster benchmark, 5% slower one (inside threshold), improved metric
        self.write(
            "BENCH_x.json",
            bench_json({"k/fast": 500.0, "k/same": 1050.0}, [("m/speed", 2.5, "x")]),
        )
        proc = self.run_delta("BENCH_x.json")
        self.assertEqual(proc.returncode, 0, proc.stderr)
        self.assertNotIn("⚠️", proc.stdout)
        self.assertIn("2.00x", proc.stdout)

    def test_custom_threshold_is_honored(self):
        self.write(os.path.join("baselines", "BENCH_x.json"), bench_json({"k/a": 1000.0}))
        self.write("BENCH_x.json", bench_json({"k/a": 1050.0}))  # 5% slower
        proc = subprocess.run(
            [
                sys.executable,
                SCRIPT,
                "--baselines",
                self.baselines,
                "--threshold",
                "0.01",
                "BENCH_x.json",
            ],
            cwd=self.dir,
            capture_output=True,
            text=True,
        )
        self.assertEqual(proc.returncode, 0, proc.stderr)
        self.assertIn("⚠️", proc.stdout)

    def test_malformed_current_file_is_skipped_not_fatal(self):
        self.write("BENCH_x.json", "{not json")
        proc = self.run_delta("BENCH_x.json")
        self.assertEqual(proc.returncode, 0, proc.stderr)
        self.assertIn("skipping", proc.stdout)

    def test_no_files_discovered_exits_zero(self):
        proc = self.run_delta()  # empty tmpdir: auto-discovery finds nothing
        self.assertEqual(proc.returncode, 0, proc.stderr)
        self.assertIn("nothing to diff", proc.stdout)

    def test_auto_discovery_picks_up_bench_json_in_cwd(self):
        self.write("BENCH_y.json", bench_json({"k/b": 2000.0}))
        proc = self.run_delta()  # no positional args
        self.assertEqual(proc.returncode, 0, proc.stderr)
        self.assertIn("BENCH_y.json", proc.stdout)
        self.assertIn("`k/b`", proc.stdout)

    def test_seeded_repo_baseline_parses_against_itself(self):
        # the committed seed baseline must stay schema-valid: diffing it
        # against itself yields 1.00x rows and no warnings
        repo_baselines = os.path.join(os.path.dirname(SCRIPT), "..", "bench", "baselines")
        seed = os.path.join(repo_baselines, "BENCH_quant_kernels.json")
        self.assertTrue(os.path.exists(seed), "seed baseline missing")
        proc = subprocess.run(
            [sys.executable, SCRIPT, "--baselines", repo_baselines, seed],
            cwd=os.path.dirname(repo_baselines),
            capture_output=True,
            text=True,
        )
        self.assertEqual(proc.returncode, 0, proc.stderr)
        self.assertNotIn("⚠️", proc.stdout)
        self.assertIn("quant/simd_speedup/avx2", proc.stdout)


if __name__ == "__main__":
    unittest.main()
