//! End-to-end serving driver (DESIGN.md §validation): load the AOT-trained
//! quantized GCN into a **prepared session** (`NativeExecutor` precomputes
//! quantized weights, NNS tables, and the resident graph's aggregation
//! plan once, then caches the full-graph logits per epoch), serve
//! concurrent node-classification requests through the coordinator
//! (router → dynamic batcher → runner), and report latency/throughput plus
//! result correctness.  After the first batch of an epoch every node
//! request is a row slice-copy; `NativeExecutor::bump_epoch` would
//! invalidate the cache on a weight/feature swap.
//!
//! ```bash
//! cargo run --release --example serve_node_level
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use a2q::coordinator::request::Payload;
use a2q::coordinator::{BatcherConfig, Coordinator, NativeExecutor};
use a2q::gnn::GnnModel;
use a2q::graph::io::{load_named, Dataset};
use a2q::runtime::ArtifactIndex;
use a2q::util::rng::Rng;

fn main() -> a2q::Result<()> {
    let artifacts = a2q::artifacts_dir();
    let index = ArtifactIndex::load(&artifacts)?;
    let artifact = index.artifact("gcn-synth-cora-a2q")?;
    let dataset = load_named(&artifacts, &artifact.dataset)?;
    let Dataset::Node(ds) = &dataset else { unreachable!() };
    let labels = ds.labels.clone();
    let num_nodes = ds.num_nodes();

    // one-time session preparation: weight quantization, NNS table sorting,
    // and plan construction all happen here, never per request
    let t_prep = Instant::now();
    let model = GnnModel::load(&artifacts, &artifact.name)?;
    let exec = Arc::new(NativeExecutor::new(model, Some(&dataset))?);
    println!(
        "prepared serving session in {:?} ({} bytes of static state)",
        t_prep.elapsed(),
        exec.prepared_bytes()
    );

    let mut coord = Coordinator::new();
    coord.add_model(
        &artifact.name,
        exec,
        BatcherConfig {
            max_wait: Duration::from_millis(5),
            ..Default::default()
        },
    );
    let coord = Arc::new(coord);

    // 4 closed-loop clients, 100 requests each, 1-8 nodes per request
    let clients = 4;
    let per_client = 100;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let coord = Arc::clone(&coord);
        let name = artifact.name.clone();
        let labels = labels.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64 + 1);
            let mut correct = 0usize;
            let mut queried = 0usize;
            for _ in 0..per_client {
                let k = rng.range(1, 9);
                let ids: Vec<u32> =
                    (0..k).map(|_| rng.below(num_nodes) as u32).collect();
                let resp = coord
                    .submit_blocking(&name, Payload::ClassifyNodes(ids.clone()))
                    .expect("request served");
                for (id, pred) in ids.iter().zip(&resp.predictions) {
                    queried += 1;
                    if pred.class as i32 == labels[*id as usize] {
                        correct += 1;
                    }
                }
            }
            (correct, queried)
        }));
    }
    let mut correct = 0usize;
    let mut queried = 0usize;
    for j in joins {
        let (c, q) = j.join().unwrap();
        correct += c;
        queried += q;
    }
    let wall = t0.elapsed();
    let snap = coord.metrics();
    println!("requests: {}   wall: {wall:?}", clients * per_client);
    println!("metrics:  {}", snap.render());
    println!(
        "node-classification agreement with labels: {:.1}% over {queried} queried nodes",
        100.0 * correct as f64 / queried as f64
    );
    println!(
        "dynamic batching amortised {:.1} requests per execution; after the \
         first batch each execution is a slice-copy off the epoch's cached logits",
        snap.mean_batch_size
    );
    Ok(())
}
