//! Accelerator design-space study: the paper's speedup claim as a function
//! of bit distribution and scheduling (§A.7.5 ablation).
//!
//! Sweeps (a) uniform bitwidths, (b) the learned power-law bit profile,
//! (c) sorted vs unsorted schedules, on a preferential-attachment graph
//! shaped like synth-cora.
//!
//! ```bash
//! cargo run --release --example accelerator_study
//! ```

use a2q::accel::{
    compare::speedup_vs_dq, simulate_model_cycles, AccelConfig, EnergyModel,
    ModelWorkload, Simulator,
};
use a2q::graph::generate::preferential_attachment;
use a2q::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let csr = preferential_attachment(&mut rng, 2708, 2);
    let dims = vec![(1433usize, 16usize), (16, 7)];

    // learned-profile bits: power-law, degree-correlated (what A²Q learns)
    let learned: Vec<u8> = (0..csr.num_nodes())
        .map(|v| match csr.in_degree(v) {
            0..=3 => 1u8,
            4..=8 => 2,
            9..=20 => 4,
            _ => 8,
        })
        .collect();

    println!("== uniform bitwidth sweep (vs DQ-INT4 baseline) ==");
    println!("{:>6} {:>12} {:>14}", "bits", "speedup", "energy-vs-gpu");
    for b in [1u8, 2, 3, 4, 6, 8] {
        let w = ModelWorkload {
            matmuls: dims.clone(),
            bits: vec![vec![b; csr.num_nodes()]; 2],
            agg_dims: vec![16, 7],
            nns_m: 0,
        };
        let sim = Simulator::new(AccelConfig::default());
        let s = speedup_vs_dq(&sim, &csr, &w);
        let e = EnergyModel::default()
            .efficiency_vs_gpu(&simulate_model_cycles(&sim, &csr, &w));
        println!("{b:>6} {s:>11.2}x {e:>13.1}x");
    }

    println!("\n== learned (degree-correlated power-law) bits ==");
    let w = ModelWorkload {
        matmuls: dims.clone(),
        bits: vec![learned.clone(), learned.clone()],
        agg_dims: vec![16, 7],
        nns_m: 0,
    };
    for (label, cfg) in [
        ("sorted schedules (paper)", AccelConfig::default()),
        ("unsorted (ablation)", AccelConfig::unsorted()),
    ] {
        let sim = Simulator::new(cfg);
        let stats = simulate_model_cycles(&sim, &csr, &w);
        let s = speedup_vs_dq(&sim, &csr, &w);
        println!(
            "{label:<28} cycles {:>12}  speedup {s:.2}x",
            stats.total_cycles()
        );
    }
    let avg: f64 =
        learned.iter().map(|&b| b as f64).sum::<f64>() / learned.len() as f64;
    println!("\nlearned avg bits {avg:.2} — the bit/degree sort recovers the");
    println!("paper's load-balancing win: lockstep tiles pay max(bits-in-tile).");
}
