//! Graph-level pipeline: Nearest-Neighbor-Strategy serving of unseen
//! molecule graphs (ZINC analogue) through the dynamic batcher.
//!
//! Demonstrates the paper's §3.3 scenario end to end: client-supplied
//! graphs of varying node counts are packed into fixed-capacity batches
//! and executed on the quantized GIN artifact through a **prepared
//! session** — the per-layer NNS tables are sorted once at session build
//! (`NativeExecutor` → `PreparedModel`), and each request only pays the
//! O(log m) per-node lookup, exactly the paper's offline/online split.
//!
//! ```bash
//! cargo run --release --example graph_level_pipeline
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use a2q::coordinator::request::Payload;
use a2q::coordinator::{BatcherConfig, Coordinator, NativeExecutor};
use a2q::gnn::GnnModel;
use a2q::graph::io::{load_named, Dataset};
use a2q::runtime::ArtifactIndex;

fn main() -> a2q::Result<()> {
    let artifacts = a2q::artifacts_dir();
    let index = ArtifactIndex::load(&artifacts)?;
    let artifact = index.artifact("gin-synth-zinc-a2q")?;
    let Dataset::Graphs(gs) = load_named(&artifacts, &artifact.dataset)? else {
        unreachable!()
    };

    // session preparation (quantized weights + integer codes + NNS tables)
    // happens once here; requests never re-derive static state
    let model = GnnModel::load(&artifacts, &artifact.name)?;
    let exec = Arc::new(NativeExecutor::new(model, None)?);
    let mut coord = Coordinator::new();
    coord.add_model(
        &artifact.name,
        exec,
        BatcherConfig {
            node_budget: artifact.num_nodes,
            graph_slots: artifact.graph_capacity.max(1),
            max_wait: Duration::from_millis(4),
            queue_cap: 512,
            ..BatcherConfig::default()
        },
    );
    let coord = Arc::new(coord);

    // submit 64 held-out molecules from 2 client threads
    let n_req = 64;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..2 {
        let coord = Arc::clone(&coord);
        let name = artifact.name.clone();
        let graphs: Vec<_> = gs
            .graphs
            .iter()
            .skip(1200 + c * n_req / 2)
            .take(n_req / 2)
            .cloned()
            .collect();
        let targets: Vec<f32> = graphs.iter().map(|g| g.target_value).collect();
        joins.push(std::thread::spawn(move || {
            let mut abs_err = 0.0f64;
            let mut sizes = Vec::new();
            for (g, t) in graphs.into_iter().zip(targets) {
                sizes.push(g.num_nodes());
                let resp = coord
                    .submit_blocking(&name, Payload::PredictGraph(g))
                    .expect("graph served");
                let pred = resp.predictions[0].output[0];
                abs_err += (pred - t).abs() as f64;
            }
            (abs_err, sizes)
        }));
    }
    let mut abs_err = 0.0;
    let mut sizes = Vec::new();
    for j in joins {
        let (e, s) = j.join().unwrap();
        abs_err += e;
        sizes.extend(s);
    }
    let wall = t0.elapsed();
    let snap = coord.metrics();
    let min_n = sizes.iter().min().unwrap();
    let max_n = sizes.iter().max().unwrap();
    println!("served {n_req} molecule graphs ({min_n}–{max_n} nodes) in {wall:?}");
    println!("metrics: {}", snap.render());
    println!("regression MAE over served graphs: {:.4}", abs_err / n_req as f64);
    println!("(recorded training MAE: {:.4})", -artifact.accuracy);
    Ok(())
}
