//! Quickstart: load the A²Q artifact, classify nodes through the PJRT
//! runtime, and compare against the FP32 and DQ-INT4 baselines.
//!
//! Run after `make artifacts`:
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use a2q::coordinator::{BatchExecutor, PjrtExecutor};
use a2q::graph::io::{load_named, Dataset};
use a2q::runtime::{ArtifactIndex, EngineHandle};

fn main() -> a2q::Result<()> {
    let artifacts = a2q::artifacts_dir();
    let index = ArtifactIndex::load(&artifacts)?;
    let engine = EngineHandle::spawn()?;
    println!("PJRT platform: {}\n", engine.platform()?);

    println!(
        "{:<28} {:>9} {:>12} {:>10} {:>10}",
        "model", "avg bits", "compression", "recorded", "measured"
    );
    for name in ["gcn-synth-cora-fp32", "gcn-synth-cora-dq", "gcn-synth-cora-a2q"] {
        let Ok(artifact) = index.artifact(name) else {
            continue;
        };
        let dataset = load_named(&artifacts, &artifact.dataset)?;
        let exec = PjrtExecutor::new(engine.clone(), &artifact, Some(&dataset))?;

        // measure test accuracy through the runtime
        let Dataset::Node(ds) = &dataset else { unreachable!() };
        let ids: Vec<u32> = (0..ds.num_nodes() as u32).collect();
        let outputs = exec.run_node_batch(&ids)?;
        let mut good = 0usize;
        let mut total = 0usize;
        for v in 0..ds.num_nodes() {
            if !ds.test_mask[v] {
                continue;
            }
            let row = &outputs[v];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            total += 1;
            if pred as i32 == ds.labels[v] {
                good += 1;
            }
        }
        println!(
            "{:<28} {:>9.2} {:>11.1}x {:>9.2}% {:>9.2}%",
            name,
            artifact.avg_bits,
            32.0 / artifact.avg_bits.max(0.01),
            artifact.accuracy * 100.0,
            100.0 * good as f64 / total as f64
        );
    }
    println!("\nA²Q: FP32-level accuracy at a fraction of the bits (paper Table 1).");
    Ok(())
}
